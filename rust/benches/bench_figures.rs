//! Regenerates the paper's Figures 2, 3, 5, 6, 7, 8, 9 (DESIGN.md §5)
//! plus the layer-wise mixed-precision Pareto series (`pareto`).
//!
//! ```bash
//! cargo bench --offline --bench bench_figures           # all figures
//! cargo bench --offline --bench bench_figures -- fig5   # one figure
//! cargo bench --offline --bench bench_figures -- pareto # layer-wise series
//! cargo bench --offline --bench bench_figures -- racing # multi-fidelity racing
//! ```
//!
//! Output: stdout + CSVs under results/ (one series per figure).
//! `QUANTUNE_THREADS` sizes the worker pool behind the sweep, search
//! fan-out, and VTA config exploration. Figures that measure through
//! PJRT are skipped with a notice when the backend is unavailable; the
//! interpreter-backed fig8 and the synthetic `pareto` series always run
//! (the latter even without artifacts).

use anyhow::Result;

use quantune::coordinator::Quantune;
use quantune::experiments as exp;
use quantune::runtime::Runtime;
use quantune::zoo;

fn need_rt<'a>(runtime: Option<&'a Runtime>, what: &str) -> Option<&'a Runtime> {
    if runtime.is_none() {
        eprintln!("[skip] {what}: needs the PJRT backend");
    }
    runtime
}

fn print_pareto(rows: &[exp::LayerwiseParetoRow]) {
    println!(
        "{:>28} | {:>9} | {:>9} | {:>11} | frontier",
        "mask", "fp32/all", "top1", "quant bytes"
    );
    for r in rows {
        println!(
            "{:>28} | {:>4}/{:<4} | {:>8.2}% | {:>11} | {}",
            r.label,
            r.fp32_layers,
            r.total_layers,
            r.accuracy * 100.0,
            r.quant_bytes,
            if r.on_frontier { "*" } else { "" }
        );
    }
}

fn print_radix_pareto(rows: &[exp::RadixParetoRow]) {
    println!(
        "{:>6} | {:>28} | {:>4} | {:>4} | {:>9} | {:>11} | frontier | dominates | picked",
        "space", "widths", "int4", "fp32", "top1", "quant bytes"
    );
    for r in rows {
        let picked = match (r.ip_baseline, r.xgb_best) {
            (true, true) => "ip+xgb",
            (true, false) => "ip",
            (false, true) => "xgb",
            (false, false) => "",
        };
        println!(
            "{:>6} | {:>28} | {:>4} | {:>4} | {:>8.2}% | {:>11} | {:>8} | {:>9} | {}",
            r.space,
            r.label,
            r.int4_layers,
            r.fp32_layers,
            r.accuracy * 100.0,
            r.quant_bytes,
            if r.on_frontier { "*" } else { "" },
            if r.dominates_best_binary { "yes" } else { "" },
            picked
        );
    }
}

fn print_aciq(rows: &[exp::AciqRow]) {
    println!(
        "{:>6} | {:>12} | {:>24} | {:>9}",
        "clip", "bias_correct", "config", "top1"
    );
    for r in rows {
        println!(
            "{:>6} | {:>12} | {:>24} | {:>8.2}%",
            r.clip.name(),
            r.bias_correct,
            r.label,
            r.top1 * 100.0
        );
    }
}

fn print_pareto_search(s: &exp::ParetoSearchSummary) {
    println!(
        "exhaustive: {} evaluations | nsga2: {} evaluations ({}% of exhaustive)",
        s.exhaustive_evals,
        s.nsga2_evals,
        100 * s.nsga2_evals / s.exhaustive_evals.max(1),
    );
    println!(
        "hypervolume: true {:.4} | nsga2 {:.4} | recovered {:.1}%",
        s.hv_true,
        s.hv_nsga2,
        s.hv_ratio * 100.0,
    );
    println!(
        "true-front configs found: {:.1}%",
        s.true_front_fraction * 100.0
    );
    println!(
        "{:>28} | {:>8} | {:>10} | {:>10} | true front | nsga2 front",
        "config", "top1", "latency ms", "bytes"
    );
    for r in s.rows.iter().filter(|r| r.on_true_front || r.on_nsga2_front) {
        println!(
            "{:>28} | {:>7.2}% | {:>10.4} | {:>10.0} | {:>10} | {}",
            r.label,
            r.accuracy * 100.0,
            r.latency_ms,
            r.size_bytes,
            if r.on_true_front { "*" } else { "" },
            if r.on_nsga2_front { "*" } else { "" }
        );
    }
}

fn print_objective_pareto(rows: &[exp::ObjectiveParetoRow]) {
    println!(
        "{:>28} | {:>8} | {:>10} | {:>10} | frontier | picked by",
        "config", "top1", "latency ms", "bytes"
    );
    for r in rows {
        println!(
            "{:>28} | {:>7.2}% | {:>10.4} | {:>10.0} | {:>8} | {}",
            r.label,
            r.accuracy * 100.0,
            r.latency_ms,
            r.size_bytes,
            if r.on_frontier { "*" } else { "" },
            r.picked_by.join("+")
        );
    }
}

fn print_racing(rows: &[exp::RacingRow]) {
    println!(
        "{:>8} | {:>10} | {:>16} | {:>16} | {:>9} | cost (full evals)",
        "stage", "algo", "exhaustive best", "racing best", "recovered"
    );
    for r in rows {
        println!(
            "{:>8} | {:>10} | {:>6} @ {:>7.4} | {:>6} @ {:>7.4} | {:>9} | \
             {:.2} vs {:.0} ({:.1}%)",
            r.stage,
            r.algo,
            r.exhaustive_best,
            r.exhaustive_score,
            r.racing_best,
            r.racing_score,
            if r.recovered { "yes" } else { "NO" },
            r.racing_cost,
            r.exhaustive_cost,
            r.cost_fraction * 100.0,
        );
    }
}

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let want = |t: &str| {
        args.iter().all(|a| a.starts_with("--")) || args.iter().any(|a| a == t)
    };

    if want("racing") {
        println!(
            "== Multi-fidelity racing: successive halving vs exhaustive \
             (synthetic, no artifacts) =="
        );
        print_racing(&exp::racing_synthetic()?);
        println!();
    }

    if want("pareto") {
        println!("== Layer-wise Pareto: synthetic fragile model (no artifacts) ==");
        print_pareto(&exp::pareto_layerwise_synthetic()?);
        println!(
            "\n== Radix Pareto: {{int4,int8,int16,fp32}} genome vs binary \
             {{int8,fp32}} masks (synthetic) =="
        );
        print_radix_pareto(&exp::pareto_radix_synthetic()?);
        println!(
            "\n== ACIQ toolbox: clipping x bias-correction on the heavy-tailed \
             synthetic model =="
        );
        print_aciq(&exp::aciq_synthetic()?);
        println!(
            "\n== Multi-objective Pareto: accuracy vs latency vs bytes \
             (synthetic, i7 profile) =="
        );
        print_objective_pareto(&exp::pareto_objectives_synthetic()?);
        println!(
            "\n== Pareto-front search: NSGA-II vs exhaustive frontier \
             (synthetic radix space) =="
        );
        print_pareto_search(&exp::pareto_search_synthetic()?);
    }

    let mut q = match Quantune::open(zoo::artifacts_dir()) {
        Ok(q) => q,
        Err(e) => {
            eprintln!("[skip] artifact-backed figures: {e:#} (run `make artifacts`)");
            return Ok(());
        }
    };
    println!(
        "worker pool: {} threads (QUANTUNE_THREADS)",
        quantune::util::pool::default_threads()
    );

    if want("pareto") {
        println!("\n== Layer-wise Pareto per model (interpreter-backed) ==");
        for name in exp::available_models(&q) {
            let model = q.load_model(&name)?;
            let base = q
                .db
                .best_general(&name)
                .map(|(c, _)| c)
                .unwrap_or_else(Quantune::tensorrt_like_baseline);
            println!("-- {name} (base {}) --", base.slug());
            let rows = exp::pareto_layerwise(
                &model,
                &q.calib_pool,
                &q.eval,
                base,
                4,
                &quantune::quant::BINARY_WIDTHS,
                q.seed,
                &format!("pareto_layerwise_{name}.csv"),
            )?;
            print_pareto(&rows);
        }
    }
    // figures 2/3/5/6/7/9 measure through PJRT; fig8 (VTA) is
    // interpreter-backed and still runs when the backend is unavailable
    let runtime = match Runtime::cpu() {
        Ok(rt) => Some(rt),
        Err(e) => {
            eprintln!("PJRT unavailable ({e})");
            None
        }
    };
    if want("fig2") {
        if let Some(rt) = need_rt(runtime.as_ref(), "fig2") {
            println!(
                "== Fig 2: Top-1 across all {} configs ==",
                quantune::quant::QuantConfig::SPACE_SIZE
            );
            let tables = exp::fig2(&mut q, rt)?;
            let mut names: Vec<&String> = tables.keys().collect();
            names.sort();
            for name in names {
                let t = &tables[name];
                let min = t.iter().cloned().fold(f64::INFINITY, f64::min);
                let max = t.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
                let fp32 = q.load_model(name)?.fp32_top1;
                println!(
                    "  {name:>5}: top1 range {:.2}%..{:.2}% (fp32 {:.2}%); relative \
                     error {:+.2}%..{:+.2}%",
                    min * 100.0,
                    max * 100.0,
                    fp32 * 100.0,
                    (min - fp32) * 100.0,
                    (max - fp32) * 100.0
                );
            }
            q.db.save()?;
        }
    }

    if want("fig3") {
        if let Some(rt) = need_rt(runtime.as_ref(), "fig3") {
            println!("\n== Fig 3: XGBoost feature importance (gain) ==");
            for (i, (name, gain)) in exp::fig3(&mut q, rt)?.iter().take(12).enumerate() {
                println!("  {:>2}. {name:<16} {:.3}", i + 1, gain);
            }
            q.db.save()?;
        }
    }

    let mut fig5_results = None;
    if want("fig5") || want("fig6") {
        if let Some(rt) = need_rt(runtime.as_ref(), "fig5") {
            println!("\n== Fig 5: convergence of the search algorithms ==");
            let seeds: Vec<u64> = (0..7).collect();
            let results = exp::fig5(&mut q, rt, &seeds, 1e-3)?;
            let mut models: Vec<String> =
                results.iter().map(|r| r.model.clone()).collect();
            models.dedup();
            print!("{:>8} |", "algo");
            for m in &models {
                print!(" {m:>6}");
            }
            println!("   (mean trials to sweep-best, {} seeds)", seeds.len());
            for algo in quantune::coordinator::PROPOSERS {
                print!("{algo:>8} |");
                for m in &models {
                    match results.iter().find(|r| &r.model == m && r.algo == algo) {
                        Some(r) => print!(" {:>6.1}", r.trials_to_best),
                        None => print!(" {:>6}", "-"),
                    }
                }
                println!();
            }
            fig5_results = Some(results);
            q.db.save()?;
        }
    }

    if want("fig6") {
        if let Some(results) = fig5_results.as_ref() {
            println!("\n== Fig 6: convergence speedup over random ==");
            for (model, algo, speedup) in exp::fig6(results)? {
                if algo != "random" {
                    println!("  {model:>5} {algo:>8}: {speedup:.2}x");
                }
            }
        } else {
            eprintln!("[skip] fig6: needs the fig5 results (PJRT backend)");
        }
    }

    if want("fig7") {
        if let Some(rt) = need_rt(runtime.as_ref(), "fig7") {
            println!("\n== Fig 7: Quantune vs fixed vendor-default baseline ==");
            println!(
                "{:>5} | {:>8} | {:>10} | {:>9} | delta",
                "model", "fp32", "baseline", "quantune"
            );
            for r in exp::fig7(&mut q, rt)? {
                println!(
                    "{:>5} | {:>7.2}% | {:>9.2}% | {:>8.2}% | {:+.2}%",
                    r.model,
                    r.fp32 * 100.0,
                    r.baseline * 100.0,
                    r.quantune * 100.0,
                    (r.quantune - r.baseline) * 100.0
                );
            }
            q.db.save()?;
        }
    }

    if want("fig8") {
        println!("\n== Fig 8: integer-only accelerator (VTA simulator) ==");
        println!(
            "{:>5} | {:>8} | {:>10} | {:>9} | {:>26} | cycles/img",
            "model", "fp32", "tvm-global", "quantune", "best cfg"
        );
        for r in exp::fig8(&q, 256)? {
            println!(
                "{:>5} | {:>7.2}% | {:>9.2}% | {:>8.2}% | {:>26} | {}",
                r.model,
                r.fp32 * 100.0,
                r.tvm_global * 100.0,
                r.quantune_best * 100.0,
                r.best_cfg.slug(),
                r.cycles_per_image
            );
        }
    }

    if want("fig9") {
        if let Some(rt) = need_rt(runtime.as_ref(), "fig9") {
            println!("\n== Fig 9: fp32 vs quantized latency (PJRT-CPU, batch 1) ==");
            println!(
                "{:>5} | {:>9} | {:>9} | {:>9} | modeled a53/i7/gpu",
                "model", "fp32 ms", "int8 ms", "speedup"
            );
            for r in exp::fig9(&q, rt, 30)? {
                let speedup = r
                    .speedup
                    .map_or_else(|| "n/a".to_string(), |s| format!("{s:.2}x"));
                println!(
                    "{:>5} | {:>9.2} | {:>9.2} | {:>9} | {:.2}/{:.2}/{:.2}",
                    r.model,
                    r.fp32_ms,
                    r.fq_ms,
                    speedup,
                    r.modeled_speedups[0],
                    r.modeled_speedups[1],
                    r.modeled_speedups[2]
                );
            }
        }
    }

    Ok(())
}
