//! Ablations of the design choices the paper discusses in §5.2.2:
//!
//!   0. search spaces: the same six algorithms (including the NSGA-II
//!      Pareto search, scored here by its scalar trace) over the general
//!      (288), VTA (12), and a layer-wise mixed-precision space through
//!      the one generic `run_search` path (always runs, no artifacts
//!      needed);
//!   1. feature preprocessing: one-hot vs categorical encoding (the paper
//!      picked one-hot because "it shows better accuracy than the
//!      categorical ones");
//!   2. XGBoost hyper-parameters (eta, max_depth) vs search convergence;
//!   3. calibration-seed sensitivity of the measured accuracy (how noisy
//!      is f(g(e, s)) itself).
//!
//! Ablations 1-3 run against the sweep ground truth in the database
//! (`quantune sweep` first), so this bench takes seconds.
//!
//! ```bash
//! cargo bench --offline --bench bench_ablation
//! ```

use anyhow::Result;

use quantune::calib::{calibrate, CalibBackend};
use quantune::coordinator::{self, Quantune, GENERAL_SPACE_TAG};
use quantune::data::synthetic_dataset;
use quantune::quant::{
    general_space, vta_space, ConfigSpace, LayerwiseSpace, QuantConfig, SpaceRef,
};
use quantune::search::{run_search, TransferRecord, XgbSearch};
use quantune::util::stats::mean;
use quantune::util::{pool, Csv, Pool};
use quantune::zoo::{self, synthetic_model};

/// Mean trials-to-optimum for an XGB search with custom space features.
/// The per-seed runs are independent and fan out across the worker pool;
/// the mean reduces in seed order, so the number matches a serial run.
fn measure_xgb(
    table: &[f64],
    feats: &[Vec<f32>],
    seeds: &[u64],
    eps: f64,
    mutate: impl Fn(&mut XgbSearch) + Sync,
) -> f64 {
    let best = table.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
    let out = Pool::auto()
        .map(seeds, |&seed| {
            let mut algo = XgbSearch::new(feats.to_vec(), seed);
            mutate(&mut algo);
            let trace = run_search(&mut algo, table.len(), |i| Ok(table[i])).unwrap();
            trace.trials_to_reach(best, eps).unwrap_or(table.len()) as f64
        })
        .expect("ablation search pool");
    mean(&out)
}

/// Ablation 0: the six algorithms over all three spaces through the one
/// generic `run_search` path, on an analytic oracle derived from each
/// space's decoded plan (clip, calib, and the fp32-layer count move the
/// score). Prints mean trials-to-optimum per (space, algorithm).
fn space_ablation(seeds: &[u64], eps: f64) -> Result<()> {
    let model = synthetic_model(8, 4, 4, 3)?;
    let calib = synthetic_dataset(64, 8, 8, 4, 4, 5);
    let cache = calibrate(
        &model,
        &calib,
        quantune::quant::CalibCount::C64,
        &CalibBackend::Interp,
        1,
    )?;
    let base = QuantConfig {
        calib: quantune::quant::CalibCount::C64,
        scheme: quantune::quant::Scheme::Symmetric,
        clip: quantune::quant::Clipping::Max,
        gran: quantune::quant::Granularity::Tensor,
        mixed: false,
        bias_correct: false,
    };
    let layerwise: SpaceRef = std::sync::Arc::new(LayerwiseSpace::rank(
        &model.name,
        &model.graph,
        model.weights_map(),
        &cache.hists,
        base,
        3,
        &quantune::quant::BINARY_WIDTHS,
    )?);
    let n_layers = model.graph.layers().len();
    let spaces: Vec<SpaceRef> = vec![general_space(), vta_space(), layerwise];

    println!("== Ablation: search spaces through the generic driver ==");
    println!(
        "{:>32} | {:>4} | {:>6} | {:>6} | {:>7} | {:>6} | {:>6} | {:>6}",
        "space", "|S|", "random", "grid", "genetic", "xgb", "xgb_t", "nsga2"
    );
    let mut csv = Csv::new(&["space", "size", "algo", "mean_trials"]);
    for space in &spaces {
        // analytic oracle over the decoded plan: every space shares it,
        // so convergence numbers are comparable across spaces
        let oracle = |i: usize| -> Result<f64> {
            let plan = space.plan(i)?;
            let mask = plan.resolve_mask(n_layers)?;
            let fp32 = mask.iter().filter(|&&b| b).count();
            Ok(0.5
                + 0.15 * (plan.base.clip == quantune::quant::Clipping::Kl) as u8 as f64
                + 0.1
                    * (plan.base.calib == quantune::quant::CalibCount::C512) as u8
                        as f64
                + 0.04 * fp32 as f64)
        };
        let best = (0..space.size())
            .map(|i| oracle(i).unwrap())
            .fold(f64::NEG_INFINITY, f64::max);
        // xgb_t warm-starts from a full "other model's" run of the same
        // oracle (the content only matters to xgb_t)
        let transfer: Vec<TransferRecord> = (0..space.size())
            .map(|i| {
                Ok(TransferRecord::full(
                    coordinator::features_for(&model, space.as_ref(), i)?,
                    oracle(i)? as f32,
                ))
            })
            .collect::<Result<_>>()?;
        print!("{:>32} | {:>4} |", space.tag(), space.size());
        for algo in ["random", "grid", "genetic", "xgb", "xgb_t", "nsga2"] {
            let per_seed = Pool::auto().map(seeds, |&seed| -> Result<f64> {
                let t = if algo == "xgb_t" { transfer.clone() } else { Vec::new() };
                let mut s = coordinator::make_algorithm(algo, &model, space, t, seed)?;
                let trace = run_search(s.as_mut(), space.size(), &oracle)?;
                Ok(trace.trials_to_reach(best, eps).unwrap_or(space.size()) as f64)
            })?;
            let per_seed: Vec<f64> = per_seed.into_iter().collect::<Result<_>>()?;
            let m = mean(&per_seed);
            print!(" {m:>6.1} |");
            csv.row(&[
                space.tag(),
                space.size().to_string(),
                algo.to_string(),
                format!("{m:.1}"),
            ]);
        }
        println!();
    }
    csv.write_file(&quantune::experiments::result_path("ablation_spaces.csv"))?;
    Ok(())
}

fn main() -> Result<()> {
    println!("worker pool: {} threads (QUANTUNE_THREADS)\n", pool::default_threads());
    let seeds: Vec<u64> = (0..7).collect();
    let eps = 1e-3;
    space_ablation(&seeds, eps)?;

    let q = match Quantune::open(zoo::artifacts_dir()) {
        Ok(q) => q,
        Err(e) => {
            eprintln!("\n[skip] artifact-backed ablations: {e:#} (run `make artifacts`)");
            return Ok(());
        }
    };
    let models: Vec<String> = zoo::MODELS
        .iter()
        .filter(|m| {
            q.db.has_full_sweep(m, GENERAL_SPACE_TAG, QuantConfig::SPACE_SIZE)
                && q.artifacts.join(format!("{m}_meta.json")).exists()
        })
        .map(|s| s.to_string())
        .collect();
    if models.is_empty() {
        eprintln!("no sweeps in the database; run `quantune sweep` first");
        return Ok(());
    }

    // ---- ablation 1: one-hot vs categorical encoding ----
    println!("== Ablation: feature preprocessing (paper §5.2.2) ==");
    println!("{:>5} | {:>10} | {:>12}", "model", "one-hot", "categorical");
    let mut csv = Csv::new(&["model", "one_hot_trials", "categorical_trials"]);
    for name in &models {
        let model = q.load_model(name)?;
        let table = q.db.accuracy_table(name, GENERAL_SPACE_TAG, QuantConfig::SPACE_SIZE);
        let arch = model.arch_features();
        let one_hot: Vec<Vec<f32>> = (0..QuantConfig::SPACE_SIZE)
            .map(|i| {
                let mut f = arch.clone();
                f.extend(QuantConfig::from_index(i).unwrap().one_hot());
                f
            })
            .collect();
        let categorical: Vec<Vec<f32>> = (0..QuantConfig::SPACE_SIZE)
            .map(|i| {
                let mut f = arch.clone();
                f.extend(QuantConfig::from_index(i).unwrap().categorical());
                f
            })
            .collect();
        let t_oh = measure_xgb(&table, &one_hot, &seeds, eps, |_| {});
        let t_cat = measure_xgb(&table, &categorical, &seeds, eps, |_| {});
        println!("{name:>5} | {t_oh:>10.1} | {t_cat:>12.1}");
        csv.row(&[name.clone(), format!("{t_oh:.1}"), format!("{t_cat:.1}")]);
    }
    csv.write_file(&quantune::experiments::result_path("ablation_encoding.csv"))?;

    // ---- ablation 2: XGBoost hyper-parameters ----
    println!("\n== Ablation: XGBoost eta / max_depth (mean over models) ==");
    let feats_for = |name: &str| -> Result<Vec<Vec<f32>>> {
        let model = q.load_model(name)?;
        let arch = model.arch_features();
        Ok((0..QuantConfig::SPACE_SIZE)
            .map(|i| {
                let mut f = arch.clone();
                f.extend(QuantConfig::from_index(i).unwrap().one_hot());
                f
            })
            .collect())
    };
    let mut csv = Csv::new(&["eta", "max_depth", "mean_trials"]);
    for eta in [0.1f32, 0.3, 0.6] {
        for depth in [2usize, 4, 6] {
            let mut per_model = Vec::new();
            for name in &models {
                let table = q.db.accuracy_table(name, GENERAL_SPACE_TAG, QuantConfig::SPACE_SIZE);
                let feats = feats_for(name)?;
                per_model.push(measure_xgb(&table, &feats, &seeds, eps, |a| {
                    a.params.eta = eta;
                    a.params.max_depth = depth;
                }));
            }
            let m = mean(&per_model);
            println!("  eta {eta:>4} depth {depth} -> {m:>5.1} trials");
            csv.row(&[eta.to_string(), depth.to_string(), format!("{m:.1}")]);
        }
    }
    csv.write_file(&quantune::experiments::result_path("ablation_hyperparams.csv"))?;

    // ---- ablation 3: eps sensitivity of the convergence metric ----
    println!("\n== Ablation: convergence epsilon (XGB, mean over models) ==");
    let mut csv = Csv::new(&["eps", "mean_trials"]);
    for e in [0.0f64, 1e-3, 5e-3, 1e-2] {
        let mut per_model = Vec::new();
        for name in &models {
            let table = q.db.accuracy_table(name, GENERAL_SPACE_TAG, QuantConfig::SPACE_SIZE);
            let feats = feats_for(name)?;
            per_model.push(measure_xgb(&table, &feats, &seeds, e, |_| {}));
        }
        let m = mean(&per_model);
        println!("  eps {e:>6}: {m:>5.1} trials");
        csv.row(&[e.to_string(), format!("{m:.1}")]);
    }
    csv.write_file(&quantune::experiments::result_path("ablation_epsilon.csv"))?;

    Ok(())
}
