//! Ablations of the design choices the paper discusses in §5.2.2:
//!
//!   1. feature preprocessing: one-hot vs categorical encoding (the paper
//!      picked one-hot because "it shows better accuracy than the
//!      categorical ones");
//!   2. XGBoost hyper-parameters (eta, max_depth) vs search convergence;
//!   3. calibration-seed sensitivity of the measured accuracy (how noisy
//!      is f(g(e, s)) itself).
//!
//! All searches run against the sweep ground truth in the database
//! (`quantune sweep` first), so this bench takes seconds.
//!
//! ```bash
//! cargo bench --offline --bench bench_ablation
//! ```

use anyhow::Result;

use quantune::coordinator::Quantune;
use quantune::quant::QuantConfig;
use quantune::search::{run_search, XgbSearch};
use quantune::util::stats::mean;
use quantune::util::{pool, Csv, Pool};
use quantune::zoo;

/// Mean trials-to-optimum for an XGB search with custom space features.
/// The per-seed runs are independent and fan out across the worker pool;
/// the mean reduces in seed order, so the number matches a serial run.
fn measure_xgb(
    table: &[f64],
    feats: &[Vec<f32>],
    seeds: &[u64],
    eps: f64,
    mutate: impl Fn(&mut XgbSearch) + Sync,
) -> f64 {
    let best = table.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
    let out = Pool::auto()
        .map(seeds, |&seed| {
            let mut algo = XgbSearch::new(feats.to_vec(), seed);
            mutate(&mut algo);
            let trace = run_search(&mut algo, table.len(), |i| Ok(table[i])).unwrap();
            trace.trials_to_reach(best, eps).unwrap_or(table.len()) as f64
        })
        .expect("ablation search pool");
    mean(&out)
}

fn main() -> Result<()> {
    println!("worker pool: {} threads (QUANTUNE_THREADS)\n", pool::default_threads());
    let q = Quantune::open(zoo::artifacts_dir())?;
    let seeds: Vec<u64> = (0..7).collect();
    let eps = 1e-3;
    let models: Vec<String> = zoo::MODELS
        .iter()
        .filter(|m| {
            q.db.has_full_sweep(m, QuantConfig::SPACE_SIZE)
                && q.artifacts.join(format!("{m}_meta.json")).exists()
        })
        .map(|s| s.to_string())
        .collect();
    if models.is_empty() {
        eprintln!("no sweeps in the database; run `quantune sweep` first");
        return Ok(());
    }

    // ---- ablation 1: one-hot vs categorical encoding ----
    println!("== Ablation: feature preprocessing (paper §5.2.2) ==");
    println!("{:>5} | {:>10} | {:>12}", "model", "one-hot", "categorical");
    let mut csv = Csv::new(&["model", "one_hot_trials", "categorical_trials"]);
    for name in &models {
        let model = q.load_model(name)?;
        let table = q.db.accuracy_table(name, QuantConfig::SPACE_SIZE);
        let arch = model.arch_features();
        let one_hot: Vec<Vec<f32>> = (0..96)
            .map(|i| {
                let mut f = arch.clone();
                f.extend(QuantConfig::from_index(i).unwrap().one_hot());
                f
            })
            .collect();
        let categorical: Vec<Vec<f32>> = (0..96)
            .map(|i| {
                let mut f = arch.clone();
                f.extend(QuantConfig::from_index(i).unwrap().categorical());
                f
            })
            .collect();
        let t_oh = measure_xgb(&table, &one_hot, &seeds, eps, |_| {});
        let t_cat = measure_xgb(&table, &categorical, &seeds, eps, |_| {});
        println!("{name:>5} | {t_oh:>10.1} | {t_cat:>12.1}");
        csv.row(&[name.clone(), format!("{t_oh:.1}"), format!("{t_cat:.1}")]);
    }
    csv.write_file(&quantune::experiments::result_path("ablation_encoding.csv"))?;

    // ---- ablation 2: XGBoost hyper-parameters ----
    println!("\n== Ablation: XGBoost eta / max_depth (mean over models) ==");
    let feats_for = |name: &str| -> Result<Vec<Vec<f32>>> {
        let model = q.load_model(name)?;
        let arch = model.arch_features();
        Ok((0..96)
            .map(|i| {
                let mut f = arch.clone();
                f.extend(QuantConfig::from_index(i).unwrap().one_hot());
                f
            })
            .collect())
    };
    let mut csv = Csv::new(&["eta", "max_depth", "mean_trials"]);
    for eta in [0.1f32, 0.3, 0.6] {
        for depth in [2usize, 4, 6] {
            let mut per_model = Vec::new();
            for name in &models {
                let table = q.db.accuracy_table(name, QuantConfig::SPACE_SIZE);
                let feats = feats_for(name)?;
                per_model.push(measure_xgb(&table, &feats, &seeds, eps, |a| {
                    a.params.eta = eta;
                    a.params.max_depth = depth;
                }));
            }
            let m = mean(&per_model);
            println!("  eta {eta:>4} depth {depth} -> {m:>5.1} trials");
            csv.row(&[eta.to_string(), depth.to_string(), format!("{m:.1}")]);
        }
    }
    csv.write_file(&quantune::experiments::result_path("ablation_hyperparams.csv"))?;

    // ---- ablation 3: eps sensitivity of the convergence metric ----
    println!("\n== Ablation: convergence epsilon (XGB, mean over models) ==");
    let mut csv = Csv::new(&["eps", "mean_trials"]);
    for e in [0.0f64, 1e-3, 5e-3, 1e-2] {
        let mut per_model = Vec::new();
        for name in &models {
            let table = q.db.accuracy_table(name, QuantConfig::SPACE_SIZE);
            let feats = feats_for(name)?;
            per_model.push(measure_xgb(&table, &feats, &seeds, e, |_| {}));
        }
        let m = mean(&per_model);
        println!("  eps {e:>6}: {m:>5.1} trials");
        csv.row(&[e.to_string(), format!("{m:.1}")]);
    }
    csv.write_file(&quantune::experiments::result_path("ablation_epsilon.csv"))?;

    Ok(())
}
