//! Hot-path micro benchmarks (EXPERIMENTS.md §Perf).
//!
//! No criterion in the offline vendor set: this is a small warmup+reps
//! harness reporting median / mean wall-clock per operation for each
//! layer's hot path:
//!   L3  interpreter conv GEMM, VTA int-GEMM forward, KL threshold
//!       search, XGBoost refit, fake-quant weight prep
//!   RT  PJRT execute (fp32 + fq, batch 128 and batch 1)
//!
//! ```bash
//! cargo bench --offline --bench bench_perf
//! ```

use anyhow::Result;

use quantune::calib::{calibrate, CalibBackend};
use quantune::coordinator::{act_params_tensor, prepare, Quantune};
use quantune::ir::Tensor;
use quantune::quant::{fake_quant_weights, Granularity, QuantConfig, Scheme};
use quantune::runtime::{tensor_to_literal, Runtime};
use quantune::util::{stats::percentile, Pcg32, Timer};
use quantune::zoo;

fn bench<F: FnMut() -> Result<()>>(name: &str, reps: usize, mut f: F) -> Result<f64> {
    // warmup
    for _ in 0..2.max(reps / 10) {
        f()?;
    }
    let mut samples = Vec::with_capacity(reps);
    for _ in 0..reps {
        let t = Timer::start();
        f()?;
        samples.push(t.ms());
    }
    let p50 = percentile(&samples, 50.0);
    let mean: f64 = samples.iter().sum::<f64>() / samples.len() as f64;
    println!("{name:<44} p50 {p50:>9.3} ms   mean {mean:>9.3} ms   ({reps} reps)");
    Ok(p50)
}

/// The pre-optimization GEMM (single rank-1 update per pass), kept for a
/// clean A/B comparison in §Perf.
fn gemm_f32_reference(m: usize, k: usize, n: usize, a: &[f32], b: &[f32], c: &mut [f32]) {
    for i in 0..m {
        let arow = &a[i * k..(i + 1) * k];
        let crow = &mut c[i * n..(i + 1) * n];
        for (p, &av) in arow.iter().enumerate() {
            if av == 0.0 {
                continue;
            }
            let brow = &b[p * n..(p + 1) * n];
            for (cv, &bv) in crow.iter_mut().zip(brow) {
                *cv += av * bv;
            }
        }
    }
}

fn main() -> Result<()> {
    let q = Quantune::open(zoo::artifacts_dir())?;
    let runtime = Runtime::cpu()?;
    let model = q.load_model("rn18")?;
    println!("perf harness on {} ({} MACs/img)\n", model.name, model.graph.macs()?);

    // ---- L3 interpreter conv (im2col + gemm) ----
    let interp = quantune::interp::Interpreter::new(&model.graph, model.weights_map());
    let x32 = q.eval.batch(&(0..32).collect::<Vec<_>>());
    bench("interp fp32 forward (batch 32)", 10, || {
        interp.forward(&x32).map(|_| ())
    })?;

    // ---- GEMM A/B: reference (pre-opt) vs current k-by-4 unroll ----
    {
        let mut rng = Pcg32::seeded(3);
        // rn18 stage-2 shape: M = 32 imgs * 16*16 px, K = 3*3*16, N = 32
        let (m, k, n) = (32 * 256, 144, 32);
        let a: Vec<f32> = (0..m * k)
            .map(|_| if rng.chance(0.5) { 0.0 } else { rng.normal() })
            .collect();
        let b: Vec<f32> = (0..k * n).map(|_| rng.normal()).collect();
        let mut c = vec![0.0f32; m * n];
        bench("gemm_f32 reference (8192x144x32)", 20, || {
            c.iter_mut().for_each(|v| *v = 0.0);
            gemm_f32_reference(m, k, n, &a, &b, &mut c);
            std::hint::black_box(&c);
            Ok(())
        })?;
        bench("gemm_f32 unrolled  (8192x144x32)", 20, || {
            c.iter_mut().for_each(|v| *v = 0.0);
            quantune::interp::gemm::gemm_f32(m, k, n, &a, &b, &mut c);
            std::hint::black_box(&c);
            Ok(())
        })?;
    }

    // ---- calibration + KL ----
    let cache = calibrate(
        &model,
        &q.calib_pool,
        quantune::quant::CalibCount::C64,
        &CalibBackend::Interp,
        q.seed,
    )?;
    bench("KL threshold search, cold (all points)", 10, || {
        // cloning + touching each histogram invalidates the memo, so
        // this measures the true first-call cost per calibration
        for h in &cache.hists {
            let mut fresh = h.clone();
            fresh.update(&[0.0]);
            std::hint::black_box(fresh.kl_threshold());
        }
        Ok(())
    })?;
    bench("KL threshold search, memoized", 20, || {
        for h in &cache.hists {
            std::hint::black_box(h.kl_threshold());
        }
        Ok(())
    })?;

    // ---- quantized-model preparation ----
    let cfg = QuantConfig::from_index(70)?;
    bench("prepare quantized setup (weights+acts)", 20, || {
        std::hint::black_box(prepare(&model, &cache, &cfg)?);
        Ok(())
    })?;
    let w = model.weights.get("conv10_w").or_else(|_| {
        model.weights.get(&format!("{}_w", model.graph.layers()[2]))
    })?;
    bench("fake-quant one conv weight (channel)", 200, || {
        std::hint::black_box(fake_quant_weights(w, Scheme::Asymmetric, Granularity::Channel));
        Ok(())
    })?;

    // ---- XGBoost refit (96 rows, 23 features) ----
    let mut rng = Pcg32::seeded(9);
    let feats: Vec<Vec<f32>> = (0..96)
        .map(|i| {
            let mut f = model.arch_features();
            f.extend(QuantConfig::from_index(i).unwrap().one_hot());
            f
        })
        .collect();
    let ys: Vec<f32> = (0..96).map(|_| rng.f32()).collect();
    bench("xgboost fit (96 rows x 23 feats, 60 trees)", 20, || {
        std::hint::black_box(quantune::xgb::XgbModel::fit(
            &feats,
            &ys,
            quantune::xgb::XgbParams::default(),
        )?);
        Ok(())
    })?;

    // ---- VTA integer forward ----
    let vcfg = quantune::quant::VtaConfig {
        calib: quantune::quant::CalibCount::C64,
        clip: quantune::quant::Clipping::Max,
        fusion: true,
    };
    let vm = quantune::vta::VtaModel::build(
        &model.graph,
        model.weights_map(),
        &cache.hists,
        &vcfg,
    )?;
    bench("VTA int-only forward (batch 32)", 10, || {
        vm.forward(&x32).map(|_| ())
    })?;

    // ---- PJRT execution ----
    let setup = prepare(&model, &cache, &cfg)?;
    let exe_fp32 = runtime.load(&q.artifacts.join(format!("{}_fp32.hlo.txt", model.name)))?;
    let exe_fq = runtime.load(&q.artifacts.join(format!("{}_fq.hlo.txt", model.name)))?;
    let x128 = q.eval.batch(&(0..q.eval.n.min(128)).collect::<Vec<_>>());
    let x_lit = tensor_to_literal(&x128)?;
    let ap = act_params_tensor(&setup);
    let ap_lit = tensor_to_literal(&ap)?;
    let w_raw: Vec<xla::Literal> = model
        .weights
        .flat()
        .iter()
        .map(|t| tensor_to_literal(t))
        .collect::<Result<_>>()?;
    let w_fq: Vec<xla::Literal> =
        setup.weights.iter().map(tensor_to_literal).collect::<Result<_>>()?;

    let mut fp32_args: Vec<&xla::Literal> = vec![&x_lit];
    fp32_args.extend(w_raw.iter());
    bench("PJRT fp32 forward (batch 128)", 20, || {
        exe_fp32.run_literals(&fp32_args).map(|_| ())
    })?;
    let mut fq_args: Vec<&xla::Literal> = vec![&x_lit, &ap_lit];
    fq_args.extend(w_fq.iter());
    bench("PJRT fq forward (batch 128)", 20, || {
        exe_fq.run_literals(&fq_args).map(|_| ())
    })?;

    // literal upload cost (the per-measure constant work)
    bench("literal upload (all rn18 weights)", 20, || {
        for t in model.weights.flat() {
            std::hint::black_box(tensor_to_literal(t)?);
        }
        Ok(())
    })?;

    // interpreter single hot conv via full fq forward
    let aq = &setup.aq;
    let weights_fq: std::collections::HashMap<String, Tensor> = model
        .weights
        .order
        .iter()
        .cloned()
        .zip(setup.weights.iter().cloned())
        .collect();
    let interp_fq = quantune::interp::Interpreter::new(&model.graph, &weights_fq);
    bench("interp fq forward (batch 32)", 10, || {
        interp_fq.forward_fq(&x32, aq).map(|_| ())
    })?;

    Ok(())
}
