//! Hot-path micro benchmarks (EXPERIMENTS.md §Perf).
//!
//! No criterion in the offline vendor set: this is a small warmup+reps
//! harness reporting median / mean wall-clock per operation.
//!
//! Two tiers:
//! - **synthetic** (always runs, no artifacts needed): the GEMM A/B
//!   (reference vs serial-unrolled vs row-tiled) and the parallel
//!   evaluation path -- a full `InterpEvaluator` Top-1 measurement over
//!   512 synthetic images at 1 thread vs the configured pool width.
//! - **artifact-backed** (skipped with a notice when `make artifacts`
//!   has not run): interpreter forwards, KL search, quantized-setup
//!   preparation with and without the weight cache, XGBoost refit, VTA
//!   forward, and -- when PJRT is available -- executable timing.
//!
//! ```bash
//! QUANTUNE_THREADS=1 cargo bench --offline --bench bench_perf
//! QUANTUNE_THREADS=4 cargo bench --offline --bench bench_perf
//! ```
//!
//! Compare the "interp evaluator measure" rows of the two runs for the
//! evaluation-path speedup (see rust/BENCHMARKS.md).

use anyhow::Result;

use quantune::calib::{calibrate, CalibBackend};
use quantune::coordinator::{
    act_params_tensor, prepare, prepare_cached, InterpEvaluator, Quantune,
    QuantizedSetup, SharedEvaluator, WeightCache,
};
use quantune::data::synthetic_dataset;
use quantune::interp::gemm::gemm_f32_tiled;
use quantune::ir::Tensor;
use quantune::quant::{
    fake_quant_weights, CalibCount, Clipping, Granularity, QuantConfig, Scheme,
};
use quantune::runtime::{tensor_to_literal, Runtime};
use quantune::util::stats::percentile;
use quantune::util::{pool, Pcg32, Timer};
use quantune::zoo::{self, synthetic_model, ZooModel};

fn bench<F: FnMut() -> Result<()>>(name: &str, reps: usize, mut f: F) -> Result<f64> {
    // warmup
    for _ in 0..2.max(reps / 10) {
        f()?;
    }
    let mut samples = Vec::with_capacity(reps);
    for _ in 0..reps {
        let t = Timer::start();
        f()?;
        samples.push(t.ms());
    }
    let p50 = percentile(&samples, 50.0);
    let mean: f64 = samples.iter().sum::<f64>() / samples.len() as f64;
    println!("{name:<44} p50 {p50:>9.3} ms   mean {mean:>9.3} ms   ({reps} reps)");
    Ok(p50)
}

/// The pre-optimization GEMM (single rank-1 update per pass), kept for a
/// clean A/B comparison in §Perf.
fn gemm_f32_reference(m: usize, k: usize, n: usize, a: &[f32], b: &[f32], c: &mut [f32]) {
    for i in 0..m {
        let arow = &a[i * k..(i + 1) * k];
        let crow = &mut c[i * n..(i + 1) * n];
        for (p, &av) in arow.iter().enumerate() {
            if av == 0.0 {
                continue;
            }
            let brow = &b[p * n..(p + 1) * n];
            for (cv, &bv) in crow.iter_mut().zip(brow) {
                *cv += av * bv;
            }
        }
    }
}

fn main() -> Result<()> {
    println!(
        "worker pool: {} threads (QUANTUNE_THREADS overrides; run once with \
         QUANTUNE_THREADS=1 and once with =4 for the speedup A/B)\n",
        pool::default_threads()
    );
    synthetic_benches()?;
    if let Err(e) = artifact_benches() {
        eprintln!("\n[skip] artifact-backed benches: {e:#} (run `make artifacts`)");
    }
    Ok(())
}

fn synthetic_benches() -> Result<()> {
    println!("== synthetic (no artifacts needed) ==");

    // ---- GEMM A/B: reference vs serial unroll vs row-tiled ----
    let mut rng = Pcg32::seeded(3);
    // rn18 stage-2 shape: M = 32 imgs * 16*16 px, K = 3*3*16, N = 32
    let (m, k, n) = (32 * 256, 144, 32);
    let a: Vec<f32> = (0..m * k)
        .map(|_| if rng.chance(0.5) { 0.0 } else { rng.normal() })
        .collect();
    let b: Vec<f32> = (0..k * n).map(|_| rng.normal()).collect();
    let mut c = vec![0.0f32; m * n];
    bench("gemm_f32 reference (8192x144x32)", 20, || {
        c.iter_mut().for_each(|v| *v = 0.0);
        gemm_f32_reference(m, k, n, &a, &b, &mut c);
        std::hint::black_box(&c);
        Ok(())
    })?;
    bench("gemm_f32 serial unrolled (8192x144x32)", 20, || {
        c.iter_mut().for_each(|v| *v = 0.0);
        gemm_f32_tiled(m, k, n, &a, &b, &mut c, 1);
        std::hint::black_box(&c);
        Ok(())
    })?;
    let threads = pool::default_threads();
    bench(&format!("gemm_f32 row-tiled x{threads} (8192x144x32)"), 20, || {
        c.iter_mut().for_each(|v| *v = 0.0);
        gemm_f32_tiled(m, k, n, &a, &b, &mut c, threads);
        std::hint::black_box(&c);
        Ok(())
    })?;

    // ---- evaluation path: full Top-1 measurement, 1 thread vs pool ----
    let model = synthetic_model(16, 8, 8, 7)?;
    let calib = synthetic_dataset(64, 16, 16, 8, 8, 21);
    let eval = synthetic_dataset(512, 16, 16, 8, 8, 22);
    let cfg_idx = QuantConfig {
        calib: CalibCount::C1,
        scheme: Scheme::Asymmetric,
        clip: Clipping::Max,
        gran: Granularity::Tensor,
        mixed: false,
        bias_correct: false,
    }
    .index();
    for threads in [1usize, pool::default_threads()] {
        // the override pins every level (batch pool AND inner GEMM), so
        // the 1-thread row is a true serial baseline even when the env
        // requests a wide pool; restore it before propagating any error
        pool::set_thread_override(Some(threads));
        let r = bench(
            &format!("interp evaluator measure (512 imgs, {threads} thr)"),
            5,
            || {
                let ev = InterpEvaluator::new(&model, &calib, &eval, 1);
                std::hint::black_box(ev.measure_shared(cfg_idx)?);
                Ok(())
            },
        );
        pool::set_thread_override(None);
        r?;
    }
    Ok(())
}

fn artifact_benches() -> Result<()> {
    let q = Quantune::open(zoo::artifacts_dir())?;
    let model = q.load_model("rn18")?;
    println!(
        "\n== artifact-backed: {} ({} MACs/img) ==",
        model.name,
        model.graph.macs()?
    );

    // ---- L3 interpreter conv (im2col + gemm) ----
    let interp = quantune::interp::Interpreter::new(&model.graph, model.weights_map());
    let x32 = q.eval.batch(&(0..32).collect::<Vec<_>>());
    bench("interp fp32 forward (batch 32)", 10, || {
        interp.forward(&x32).map(|_| ())
    })?;

    // ---- calibration + KL ----
    let cache = calibrate(
        &model,
        &q.calib_pool,
        CalibCount::C64,
        &CalibBackend::Interp,
        q.seed,
    )?;
    bench("KL threshold search, cold (all points)", 10, || {
        // cloning + touching each histogram invalidates the memo, so
        // this measures the true first-call cost per calibration
        for h in &cache.hists {
            let mut fresh = h.clone();
            fresh.update(&[0.0]);
            std::hint::black_box(fresh.kl_threshold());
        }
        Ok(())
    })?;
    bench("KL threshold search, memoized", 20, || {
        for h in &cache.hists {
            std::hint::black_box(h.kl_threshold());
        }
        Ok(())
    })?;

    // ---- quantized-model preparation: cold vs warm weight cache ----
    let plan: quantune::quant::QuantPlan = QuantConfig::from_index(70)?.into();
    bench("prepare quantized setup (no cache)", 20, || {
        std::hint::black_box(prepare(&model, &cache, &plan)?);
        Ok(())
    })?;
    let wcache = WeightCache::new();
    prepare_cached(&model, &cache, &plan, &wcache)?;
    bench("prepare quantized setup (warm cache)", 20, || {
        std::hint::black_box(prepare_cached(&model, &cache, &plan, &wcache)?);
        Ok(())
    })?;
    let w = model.weights.get("conv10_w").or_else(|_| {
        model.weights.get(&format!("{}_w", model.graph.layers()[2]))
    })?;
    bench("fake-quant one conv weight (channel)", 200, || {
        std::hint::black_box(fake_quant_weights(w, Scheme::Asymmetric, Granularity::Channel));
        Ok(())
    })?;

    // ---- XGBoost refit (96 rows, 23 features) ----
    let mut rng = Pcg32::seeded(9);
    let feats: Vec<Vec<f32>> = (0..96)
        .map(|i| {
            let mut f = model.arch_features();
            f.extend(QuantConfig::from_index(i).unwrap().one_hot());
            f
        })
        .collect();
    let ys: Vec<f32> = (0..96).map(|_| rng.f32()).collect();
    bench("xgboost fit (96 rows x 23 feats, 60 trees)", 20, || {
        std::hint::black_box(quantune::xgb::XgbModel::fit(
            &feats,
            &ys,
            quantune::xgb::XgbParams::default(),
        )?);
        Ok(())
    })?;

    // ---- VTA integer forward ----
    let vcfg = quantune::quant::VtaConfig {
        calib: CalibCount::C64,
        clip: Clipping::Max,
        fusion: true,
    };
    let vm = quantune::vta::VtaModel::build(
        &model.graph,
        model.weights_map(),
        &cache.hists,
        &vcfg,
    )?;
    bench("VTA int-only forward (batch 32)", 10, || {
        vm.forward(&x32).map(|_| ())
    })?;

    // ---- interpreter fq forward via full setup ----
    let setup = prepare(&model, &cache, &plan)?;
    let aq = &setup.aq;
    let weights_fq: std::collections::HashMap<String, std::sync::Arc<Tensor>> = model
        .weights
        .order
        .iter()
        .cloned()
        .zip(setup.weights.iter().cloned())
        .collect();
    let interp_fq = quantune::interp::Interpreter::new(&model.graph, &weights_fq);
    bench("interp fq forward (batch 32)", 10, || {
        interp_fq.forward_fq(&x32, aq).map(|_| ())
    })?;

    // ---- PJRT execution (skipped when the backend is unavailable) ----
    match Runtime::cpu() {
        Ok(runtime) => pjrt_benches(&q, &model, &runtime, &setup)?,
        Err(e) => eprintln!("[skip] PJRT benches: {e}"),
    }
    Ok(())
}

fn pjrt_benches(
    q: &Quantune,
    model: &ZooModel,
    runtime: &Runtime,
    setup: &QuantizedSetup,
) -> Result<()> {
    let exe_fp32 = runtime.load(&q.artifacts.join(format!("{}_fp32.hlo.txt", model.name)))?;
    let exe_fq = runtime.load(&q.artifacts.join(format!("{}_fq.hlo.txt", model.name)))?;
    let x128 = q.eval.batch(&(0..q.eval.n.min(128)).collect::<Vec<_>>());
    let x_lit = tensor_to_literal(&x128)?;
    let ap = act_params_tensor(setup);
    let ap_lit = tensor_to_literal(&ap)?;
    let w_raw: Vec<xla::Literal> = model
        .weights
        .flat()
        .iter()
        .map(|t| tensor_to_literal(t))
        .collect::<Result<_>>()?;
    let w_fq: Vec<xla::Literal> = setup
        .weights
        .iter()
        .map(|t| tensor_to_literal(t))
        .collect::<Result<_>>()?;

    let mut fp32_args: Vec<&xla::Literal> = vec![&x_lit];
    fp32_args.extend(w_raw.iter());
    bench("PJRT fp32 forward (batch 128)", 20, || {
        exe_fp32.run_literals(&fp32_args).map(|_| ())
    })?;
    let mut fq_args: Vec<&xla::Literal> = vec![&x_lit, &ap_lit];
    fq_args.extend(w_fq.iter());
    bench("PJRT fq forward (batch 128)", 20, || {
        exe_fq.run_literals(&fq_args).map(|_| ())
    })?;

    // literal upload cost (the per-measure constant work)
    bench("literal upload (all rn18 weights)", 20, || {
        for t in model.weights.flat() {
            std::hint::black_box(tensor_to_literal(t)?);
        }
        Ok(())
    })?;
    Ok(())
}
