//! End-to-end integer interpreter benchmark (BENCHMARKS.md §Kernel
//! engine).
//!
//! Where `bench_kernels` A/Bs isolated GEMM microkernels, this bench
//! measures whole fake-quant forwards through the interpreter and
//! persists the numbers to `BENCH_interp.json`. Three variants per row:
//! - `fq_f32`     -- the legacy route: f32 GEMM over fake-quantized
//!   values, no integer weights attached (reused scratch arena);
//! - `int_repack` -- the integer route with *nothing* reused across
//!   forwards: every pass re-packs every weight panel, rebuilds the
//!   interpreter plans, and brings a cold scratch arena. This is the
//!   per-call-packing shape of the engine before prepacking landed;
//! - `int_steady` -- the PR-7 steady state: panels packed once in
//!   `prepare_cached`, one scratch arena reused across passes.
//!
//! Correctness gates run before any timing: `int_steady` and
//! `int_repack` logits must be bitwise identical (independently packed
//! panels, same integer math), both must predict the same classes as
//! the f32 route, and the steady loop must perform **zero** `pack_b_*`
//! calls and at most a handful of heap allocations per forward -- the
//! process allocator is wrapped in a counting shim to enforce that.
//!
//! The model set pairs the conv-dominated `syn8` (where packing is
//! amortized over many output pixels) with a dense-heavy `dense_head`
//! at batch 1, where every dense GEMM has one output row and per-call
//! packing costs as much as the GEMM itself -- the regime the prepack
//! cache exists for.
//!
//! ```bash
//! cargo bench --offline --bench bench_interp            # full reps
//! cargo bench --offline --bench bench_interp -- --smoke # CI smoke
//! cargo bench --offline --bench bench_interp -- --out path.json
//! ```

use std::alloc::{GlobalAlloc, Layout, System};
use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use anyhow::Result;

use quantune::calib::{calibrate, CalibBackend};
use quantune::coordinator::{prepare_cached, QuantizedSetup, WeightCache};
use quantune::data::{synthetic_dataset, Weights};
use quantune::interp::kernels::pack_calls;
use quantune::interp::{argmax_batch, InterpScratch, Interpreter, PreparedWeight};
use quantune::ir::{Graph, Op, Tensor};
use quantune::quant::{
    CalibCount, Clipping, Granularity, QuantConfig, QuantPlan, Scheme,
};
use quantune::util::stats::percentile;
use quantune::util::{pool, Json, Pcg32, Timer};
use quantune::zoo::{synthetic_model, ZooModel};

/// Counting shim around the system allocator: bumps a global tally on
/// every alloc/realloc so the bench can assert the steady-state forward
/// loop is allocation-free (modulo the returned logits).
struct CountingAlloc;

static ALLOCS: AtomicU64 = AtomicU64::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc(layout)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }
}

#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

fn bench<F: FnMut() -> Result<()>>(name: &str, reps: usize, mut f: F) -> Result<(f64, f64)> {
    for _ in 0..2.max(reps / 10) {
        f()?;
    }
    let mut samples = Vec::with_capacity(reps);
    for _ in 0..reps {
        let t = Timer::start();
        f()?;
        samples.push(t.ms());
    }
    let p50 = percentile(&samples, 50.0);
    let mean: f64 = samples.iter().sum::<f64>() / samples.len() as f64;
    println!("{name:<44} p50 {p50:>9.3} ms   mean {mean:>9.3} ms   ({reps} reps)");
    Ok((p50, mean))
}

/// Allocations per call of `f`, averaged over `reps` quiet runs (no
/// timing machinery in the loop).
fn allocs_per_call<F: FnMut() -> Result<()>>(reps: usize, mut f: F) -> Result<f64> {
    f()?; // warm once so one-time growth is not billed to the loop
    let a0 = ALLOCS.load(Ordering::Relaxed);
    for _ in 0..reps {
        f()?;
    }
    Ok((ALLOCS.load(Ordering::Relaxed) - a0) as f64 / reps as f64)
}

/// A dense-heavy head: one small conv, then three dense layers with a
/// 256-wide trunk. At batch 1 every dense GEMM has a single output row,
/// so per-call panel packing costs as much as the matmul it feeds.
fn dense_head(seed: u64) -> Result<ZooModel> {
    let meta_text = r#"{"name": "dense_head", "input_shape": [8, 8, 4], "num_classes": 4,
      "nodes": [
        {"name": "c1", "op": "conv", "inputs": ["input"], "k": 3, "stride": 1,
         "pad": 1, "in_ch": 4, "out_ch": 8, "groups": 1, "act": "relu"},
        {"name": "g", "op": "gap", "inputs": ["c1"]},
        {"name": "d1", "op": "dense", "inputs": ["g"], "in_dim": 8, "out_dim": 256},
        {"name": "d2", "op": "dense", "inputs": ["d1"], "in_dim": 256, "out_dim": 256},
        {"name": "d3", "op": "dense", "inputs": ["d2"], "in_dim": 256, "out_dim": 4}]}"#;
    let graph = Graph::from_meta(&Json::parse(meta_text)?)?;
    let mut rng = Pcg32::new(seed, 41);
    let mut tensors = HashMap::new();
    let mut order = Vec::new();
    for node in &graph.nodes {
        let (w_shape, b_len): (Vec<usize>, usize) = match &node.op {
            Op::Conv { k, in_ch, out_ch, groups, .. } => {
                (vec![*k, *k, in_ch / groups, *out_ch], *out_ch)
            }
            Op::Dense { in_dim, out_dim } => (vec![*in_dim, *out_dim], *out_dim),
            _ => continue,
        };
        let fan_in: usize = w_shape[..w_shape.len() - 1].iter().product();
        let scale = (2.0 / fan_in.max(1) as f32).sqrt();
        let wn: usize = w_shape.iter().product();
        let w = Tensor {
            shape: w_shape,
            data: (0..wn).map(|_| rng.normal() * scale).collect(),
        };
        let b = Tensor {
            shape: vec![b_len],
            data: (0..b_len).map(|_| rng.normal() * 0.05).collect(),
        };
        for (suffix, t) in [("w", w), ("b", b)] {
            let name = format!("{}_{suffix}", node.name);
            order.push(name.clone());
            tensors.insert(name, t);
        }
    }
    Ok(ZooModel {
        name: "dense_head".to_string(),
        graph,
        weights: Weights { tensors, order },
        fp32_top1: 0.5,
        batch: 16,
    })
}

fn variant_row(p50: f64, mean: f64, batch: usize) -> Json {
    Json::obj(vec![
        ("p50_ms", Json::num(p50)),
        ("mean_ms", Json::num(mean)),
        ("ms_per_image", Json::num(p50 / batch as f64)),
    ])
}

fn max_abs_diff(a: &Tensor, b: &Tensor) -> f32 {
    a.data
        .iter()
        .zip(&b.data)
        .map(|(x, y)| (x - y).abs())
        .fold(0.0f32, f32::max)
}

/// Pack every panel of `setup` from scratch into a fresh map -- the
/// per-forward cost the `int_repack` baseline pays.
fn repack_all(setup: &QuantizedSetup) -> Result<HashMap<String, Arc<PreparedWeight>>> {
    let mut out = HashMap::with_capacity(setup.int_weights.len());
    for (name, pw) in &setup.int_weights {
        out.insert(
            name.clone(),
            Arc::new(PreparedWeight::pack(pw.qw().clone(), pw.groups())?),
        );
    }
    Ok(out)
}

fn bench_model(model: &ZooModel, batch: usize, scheme: Scheme, reps: usize) -> Result<Json> {
    println!("\n-- {} @ batch {batch}, {scheme:?} --", model.name);
    let calib = synthetic_dataset(16, 8, 8, 4, 4, 5);
    let eval = synthetic_dataset(batch, 8, 8, 4, 4, 6);
    let cache = calibrate(model, &calib, CalibCount::C1, &CalibBackend::Interp, 1)?;
    let base = QuantConfig {
        calib: CalibCount::C1,
        scheme,
        clip: Clipping::Max,
        gran: Granularity::Channel,
        mixed: false,
        bias_correct: false,
    };
    let plan = QuantPlan { base, layer_widths: None };
    let setup = prepare_cached(model, &cache, &plan, &WeightCache::new())?;
    let weights: HashMap<String, Arc<Tensor>> = model
        .weights
        .order
        .iter()
        .cloned()
        .zip(setup.weights.iter().cloned())
        .collect();
    let x = eval.batch(&(0..batch).collect::<Vec<_>>());

    let f32_route = Interpreter::new(&model.graph, &weights);
    let steady_route = Interpreter::new(&model.graph, &weights)
        .with_int_weights(&setup.int_weights);

    // -- correctness gates, before any timing -------------------------
    let ref_f32 = f32_route.forward_fq(&x, &setup.aq)?;
    let mut scratch = InterpScratch::for_graph(&model.graph, batch);
    let ref_steady = steady_route.forward_fq_with(&x, &setup.aq, &mut scratch)?;
    let repacked = repack_all(&setup)?;
    let repack_route =
        Interpreter::new(&model.graph, &weights).with_int_weights(&repacked);
    let ref_repack = repack_route.forward_fq(&x, &setup.aq)?;
    anyhow::ensure!(
        ref_steady.data == ref_repack.data,
        "{}: prepacked and freshly packed panels disagree bitwise",
        model.name
    );
    anyhow::ensure!(
        argmax_batch(&ref_steady) == argmax_batch(&ref_f32),
        "{}: integer route flipped a Top-1 prediction vs the f32 route",
        model.name
    );
    let diff_f32 = max_abs_diff(&ref_steady, &ref_f32);

    // -- steady-state no-pack / no-alloc assertions -------------------
    let packs0 = pack_calls();
    let allocs_steady = allocs_per_call(reps, || {
        let logits = steady_route.forward_fq_with(&x, &setup.aq, &mut scratch)?;
        std::hint::black_box(&logits);
        Ok(())
    })?;
    anyhow::ensure!(
        pack_calls() == packs0,
        "{}: steady-state forwards re-packed a weight panel",
        model.name
    );
    // the returned logits tensor (shape + data vecs) is the only
    // steady-state allocation the arena design permits
    anyhow::ensure!(
        allocs_steady <= 4.0,
        "{}: steady-state forward allocates ({allocs_steady:.1}/fwd)",
        model.name
    );
    let packs_before_repack = pack_calls();
    let mut repack_fwds = 0u64;
    let allocs_repack = allocs_per_call(reps, || {
        let fresh = repack_all(&setup)?;
        let route = Interpreter::new(&model.graph, &weights).with_int_weights(&fresh);
        let logits = route.forward_fq_with(&x, &setup.aq, &mut InterpScratch::new())?;
        std::hint::black_box(&logits);
        repack_fwds += 1;
        Ok(())
    })?;
    anyhow::ensure!(
        allocs_steady < allocs_repack,
        "{}: repack baseline should out-allocate the arena path",
        model.name
    );
    let packs_per_repack_fwd =
        (pack_calls() - packs_before_repack) as f64 / repack_fwds as f64;

    // -- timing -------------------------------------------------------
    let mut variants = Vec::new();
    let (p50_f32, mean) = bench("fq_f32 (fake-quant f32 GEMM)", reps, || {
        let logits = f32_route.forward_fq_with(&x, &setup.aq, &mut scratch)?;
        std::hint::black_box(&logits);
        Ok(())
    })?;
    variants.push(("fq_f32", variant_row(p50_f32, mean, batch)));

    let (p50_repack, mean) = bench("int_repack (pack every forward)", reps, || {
        let fresh = repack_all(&setup)?;
        let route = Interpreter::new(&model.graph, &weights).with_int_weights(&fresh);
        let logits = route.forward_fq_with(&x, &setup.aq, &mut InterpScratch::new())?;
        std::hint::black_box(&logits);
        Ok(())
    })?;
    variants.push(("int_repack", variant_row(p50_repack, mean, batch)));

    let (p50_steady, mean) = bench("int_steady (prepacked + arena)", reps, || {
        let logits = steady_route.forward_fq_with(&x, &setup.aq, &mut scratch)?;
        std::hint::black_box(&logits);
        Ok(())
    })?;
    variants.push(("int_steady", variant_row(p50_steady, mean, batch)));

    let speedup_repack = p50_repack / p50_steady;
    let speedup_f32 = p50_f32 / p50_steady;
    println!(
        "   int_steady speedup: {speedup_repack:.2}x vs int_repack, \
         {speedup_f32:.2}x vs fq_f32 (steady {allocs_steady:.1} allocs/fwd, \
         repack {allocs_repack:.1})"
    );
    Ok(Json::obj(vec![
        ("model", Json::str(&model.name)),
        ("batch", Json::num(batch as f64)),
        ("scheme", Json::str(&format!("{scheme:?}"))),
        ("variants", Json::obj(variants)),
        ("speedup_vs_repack", Json::num(speedup_repack)),
        ("speedup_vs_f32", Json::num(speedup_f32)),
        ("allocs_per_fwd_steady", Json::num(allocs_steady)),
        ("allocs_per_fwd_repack", Json::num(allocs_repack)),
        ("pack_calls_per_fwd_steady", Json::num(0.0)),
        ("pack_calls_per_fwd_repack", Json::num(packs_per_repack_fwd)),
        ("max_abs_diff_vs_f32", Json::num(diff_f32 as f64)),
    ]))
}

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().collect();
    let smoke = args.iter().any(|a| a == "--smoke");
    let out_path = args
        .iter()
        .position(|a| a == "--out")
        .and_then(|i| args.get(i + 1))
        .cloned()
        .unwrap_or_else(|| "BENCH_interp.json".to_string());

    // single thread: this bench measures the engine, not the pool
    pool::set_thread_override(Some(1));
    let reps = if smoke { 5 } else { 200 };
    println!(
        "integer pipeline A/B: {} reps/variant, single-thread (see \
         BENCHMARKS.md \u{00a7}Kernel engine)",
        reps
    );

    let syn8 = synthetic_model(8, 4, 4, 3)?;
    let dense = dense_head(7)?;
    let rows = vec![
        bench_model(&syn8, 16, Scheme::Asymmetric, reps)?,
        bench_model(&syn8, 1, Scheme::Asymmetric, reps)?,
        bench_model(&dense, 1, Scheme::Asymmetric, reps)?,
        bench_model(&dense, 1, Scheme::Symmetric, reps)?,
    ];

    let report = Json::obj(vec![
        ("threads", Json::num(1.0)),
        ("smoke", Json::Bool(smoke)),
        (
            "variants",
            Json::Arr(
                ["fq_f32", "int_repack", "int_steady"]
                    .iter()
                    .map(|v| Json::str(*v))
                    .collect(),
            ),
        ),
        ("rows", Json::Arr(rows)),
    ]);
    report.write_file(std::path::Path::new(&out_path))?;
    println!("\nwrote {out_path}");
    Ok(())
}
