//! Regenerates the paper's Tables 1-5 (see DESIGN.md §5).
//!
//! ```bash
//! cargo bench --offline --bench bench_tables            # all tables
//! cargo bench --offline --bench bench_tables -- table1  # one table
//! ```
//!
//! Output: stdout + CSVs under results/. `QUANTUNE_THREADS` sizes the
//! worker pool. Tables 1/2/4 measure through PJRT and are skipped with a
//! notice when the backend is unavailable; tables 3/5 always run.

use anyhow::Result;

use quantune::coordinator::Quantune;
use quantune::experiments as exp;
use quantune::runtime::Runtime;
use quantune::zoo;

fn need_rt<'a>(runtime: Option<&'a Runtime>, what: &str) -> Option<&'a Runtime> {
    if runtime.is_none() {
        eprintln!("[skip] {what}: needs the PJRT backend");
    }
    runtime
}

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let want = |t: &str| {
        args.iter().all(|a| a.starts_with("--")) || args.iter().any(|a| a == t)
    };

    // table 3 is pure computation: it runs even without artifacts
    if want("table3") {
        println!("== Table 3: scheme comparison (computed) ==");
        println!(
            "{:>16} | {:>12} | {:>12} | {:>6} | int-only",
            "scheme", "mse(gauss)", "mse(skewed)", "ops"
        );
        for r in exp::table3()? {
            println!(
                "{:>16} | {:>12.3e} | {:>12.3e} | {:>6} | {}",
                r.scheme.name(),
                r.mse_gaussian,
                r.mse_skewed,
                r.ops_per_value,
                r.integer_only
            );
        }
    }

    let mut q = match Quantune::open(zoo::artifacts_dir()) {
        Ok(q) => q,
        Err(e) => {
            eprintln!("[skip] artifact-backed tables: {e:#} (run `make artifacts`)");
            return Ok(());
        }
    };
    println!(
        "worker pool: {} threads (QUANTUNE_THREADS)",
        quantune::util::pool::default_threads()
    );
    let runtime = match Runtime::cpu() {
        Ok(rt) => Some(rt),
        Err(e) => {
            eprintln!("PJRT unavailable ({e})");
            None
        }
    };
    if want("table1") {
        if let Some(rt) = need_rt(runtime.as_ref(), "table1") {
            println!("== Table 1: best configuration per model ==");
            println!(
                "{:>5} | {:>9} | {:>7} | {:>8} | {:>4} | {:>15} | accuracy",
                "model", "precision", "#calib", "gran", "clip", "scheme"
            );
            for r in exp::table1(&mut q, rt)? {
                println!(
                    "{:>5} | {:>9} | {:>7} | {:>8} | {:>4} | {:>15} | {}",
                    r.model,
                    if r.best.mixed { "int8+fp32" } else { "int8" },
                    r.best.calib.paper_images(),
                    format!("{:?}", r.best.gran),
                    format!("{:?}", r.best.clip),
                    r.best.scheme.name(),
                    r.accuracy_cell(),
                );
            }
            q.db.save()?;
        }
    }

    if want("table2") {
        if let Some(rt) = need_rt(runtime.as_ref(), "table2") {
            println!("\n== Table 2: accuracy-measurement cost ==");
            println!(
                "{:>5} | {:>12} | {:>10} | {:>10} | {:>10}",
                "model", "host (s)", "a53 (h)", "i7 (h)", "2080ti (h)"
            );
            for r in exp::table2(&mut q, rt)? {
                println!(
                    "{:>5} | {:>12.2} | {:>10.2} | {:>10.3} | {:>10.4}",
                    r.model,
                    r.measured_host_secs,
                    r.modeled_hours[0],
                    r.modeled_hours[1],
                    r.modeled_hours[2]
                );
            }
        }
    }

    if want("table4") {
        if let Some(rt) = need_rt(runtime.as_ref(), "table4") {
            println!("\n== Table 4: diversity (Shannon entropy) of <=1%-loss configs ==");
            let d = exp::table4(&mut q, rt, 0.01)?;
            println!(
                "precision {:.2} | calibration {:.2} | granularity {:.2} | \
                 clipping {:.2} | scheme {:.2} | samples {}",
                d.precision, d.calibration, d.granularity, d.clipping, d.scheme,
                d.num_samples
            );
            println!("no universal config: {}", d.no_universal_config());
            q.db.save()?;
        }
    }

    if want("table5") {
        println!("\n== Table 5: quantized model size ==");
        println!(
            "{:>5} | {:>10} | {:>10} | {:>10} | {:>12} | {:>13}",
            "model", "original", "tensor", "channel", "tensor+mixed", "channel+mixed"
        );
        for r in exp::table5(&q)? {
            let kb = |b: u64| format!("{:.2}KB", b as f64 / 1024.0);
            println!(
                "{:>5} | {:>10} | {:>10} | {:>10} | {:>12} | {:>13}",
                r.model,
                kb(r.original),
                kb(r.tensor),
                kb(r.channel),
                kb(r.tensor_mixed),
                kb(r.channel_mixed)
            );
        }
    }

    Ok(())
}
