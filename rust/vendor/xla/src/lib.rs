//! Offline stub of the `xla` PJRT bindings.
//!
//! The quantune crate's HLO accuracy-measurement backend drives XLA
//! through the `xla` bindings (PJRT CPU client, literal upload, compiled
//! HLO-text executables). Those bindings link a native `xla_extension`
//! library that is not available in the offline build environment, so
//! this stub provides the exact API surface quantune uses:
//!
//! - host-side [`Literal`] construction (`vec1`, `reshape`, `to_vec`,
//!   `convert`, `array_shape`, `ty`) is fully functional, so tensor
//!   marshalling code runs and is testable;
//! - device entry points ([`PjRtClient::cpu`],
//!   [`HloModuleProto::from_text_file`], execution) return a descriptive
//!   error, so every PJRT-dependent path fails fast with a clear message
//!   instead of breaking the build.
//!
//! To enable the real backend, replace the `xla = { path = ... }`
//! dependency in rust/Cargo.toml with the actual bindings; no quantune
//! source changes are required.

use std::borrow::Borrow;
use std::fmt;

/// Stub error: carries a human-readable message, like the real crate.
#[derive(Debug, Clone)]
pub struct Error(String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn unavailable(what: &str) -> Error {
    Error(format!(
        "{what}: XLA/PJRT is not available in this build (the vendored `xla` \
         crate is an offline stub; swap rust/vendor/xla for the real bindings \
         to enable the HLO backend)"
    ))
}

/// Element type of a literal (subset the coordinator inspects).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ElementType {
    Pred,
    S32,
    S64,
    U8,
    F32,
    F64,
}

/// Conversion target type (subset the coordinator requests).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PrimitiveType {
    Pred,
    S32,
    S64,
    U8,
    F32,
    F64,
}

/// Typed storage behind a literal. Public only so [`NativeType`] can name
/// it; treat as an implementation detail.
#[doc(hidden)]
#[derive(Clone, Debug)]
pub enum Data {
    F32(Vec<f32>),
    F64(Vec<f64>),
    I32(Vec<i32>),
    I64(Vec<i64>),
    U8(Vec<u8>),
}

impl Data {
    fn len(&self) -> usize {
        match self {
            Data::F32(v) => v.len(),
            Data::F64(v) => v.len(),
            Data::I32(v) => v.len(),
            Data::I64(v) => v.len(),
            Data::U8(v) => v.len(),
        }
    }

    fn ty(&self) -> ElementType {
        match self {
            Data::F32(_) => ElementType::F32,
            Data::F64(_) => ElementType::F64,
            Data::I32(_) => ElementType::S32,
            Data::I64(_) => ElementType::S64,
            Data::U8(_) => ElementType::U8,
        }
    }
}

/// Rust scalar types a literal can hold.
pub trait NativeType: Sized + Copy {
    #[doc(hidden)]
    fn wrap(data: Vec<Self>) -> Data;
    #[doc(hidden)]
    fn unwrap(data: &Data) -> Option<Vec<Self>>;
}

macro_rules! native {
    ($t:ty, $variant:ident) => {
        impl NativeType for $t {
            fn wrap(data: Vec<Self>) -> Data {
                Data::$variant(data)
            }
            fn unwrap(data: &Data) -> Option<Vec<Self>> {
                match data {
                    Data::$variant(v) => Some(v.clone()),
                    _ => None,
                }
            }
        }
    };
}

native!(f32, F32);
native!(f64, F64);
native!(i32, I32);
native!(i64, I64);
native!(u8, U8);

/// Host-side array shape (dims only; layout is irrelevant here).
#[derive(Clone, Debug)]
pub struct ArrayShape {
    dims: Vec<i64>,
}

impl ArrayShape {
    pub fn dims(&self) -> &[i64] {
        &self.dims
    }
}

/// Host-side tensor literal. Fully functional in the stub.
#[derive(Clone, Debug)]
pub struct Literal {
    data: Data,
    dims: Vec<i64>,
}

impl Literal {
    /// Rank-1 literal from a slice.
    pub fn vec1<T: NativeType>(data: &[T]) -> Literal {
        Literal { dims: vec![data.len() as i64], data: T::wrap(data.to_vec()) }
    }

    pub fn element_count(&self) -> usize {
        self.data.len()
    }

    /// Same data, new dims (element count must match).
    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        let want: i64 = dims.iter().product();
        if want as usize != self.data.len() {
            return Err(Error(format!(
                "reshape to {dims:?}: want {want} elements, literal has {}",
                self.data.len()
            )));
        }
        Ok(Literal { data: self.data.clone(), dims: dims.to_vec() })
    }

    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        T::unwrap(&self.data)
            .ok_or_else(|| Error(format!("to_vec: literal holds {:?}", self.data.ty())))
    }

    /// Decompose a tuple literal. The stub never produces tuples (they
    /// only come back from device execution), so this always errors.
    pub fn to_tuple(&self) -> Result<Vec<Literal>> {
        Err(unavailable("to_tuple"))
    }

    pub fn array_shape(&self) -> Result<ArrayShape> {
        Ok(ArrayShape { dims: self.dims.clone() })
    }

    pub fn ty(&self) -> Result<ElementType> {
        Ok(self.data.ty())
    }

    /// Element-type conversion (host side; f32 target only, which is all
    /// the coordinator requests).
    pub fn convert(&self, ty: PrimitiveType) -> Result<Literal> {
        match ty {
            PrimitiveType::F32 => {
                let data = match &self.data {
                    Data::F32(v) => v.clone(),
                    Data::F64(v) => v.iter().map(|&x| x as f32).collect(),
                    Data::I32(v) => v.iter().map(|&x| x as f32).collect(),
                    Data::I64(v) => v.iter().map(|&x| x as f32).collect(),
                    Data::U8(v) => v.iter().map(|&x| x as f32).collect(),
                };
                Ok(Literal { data: Data::F32(data), dims: self.dims.clone() })
            }
            other => Err(Error(format!("convert to {other:?}: unsupported in stub"))),
        }
    }
}

/// Device buffer handle returned by execution. Unconstructible in the
/// stub (execution always errors first).
pub struct PjRtBuffer {
    _priv: (),
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(unavailable("to_literal_sync"))
    }
}

/// Compiled executable handle. Unconstructible in the stub.
pub struct PjRtLoadedExecutable {
    _priv: (),
}

impl PjRtLoadedExecutable {
    pub fn execute<L: Borrow<Literal>>(&self, _args: &[L]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(unavailable("execute"))
    }
}

/// PJRT client handle.
pub struct PjRtClient {
    _priv: (),
}

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Err(unavailable("PjRtClient::cpu"))
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(unavailable("compile"))
    }
}

/// Parsed HLO module handle.
pub struct HloModuleProto {
    _priv: (),
}

impl HloModuleProto {
    pub fn from_text_file(path: &str) -> Result<HloModuleProto> {
        Err(unavailable(&format!("parsing HLO text {path}")))
    }
}

/// Computation wrapper around a parsed module.
pub struct XlaComputation {
    _priv: (),
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { _priv: () }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip() {
        let l = Literal::vec1(&[1.0f32, 2.0, 3.0, 4.0]);
        let r = l.reshape(&[2, 2]).unwrap();
        assert_eq!(r.array_shape().unwrap().dims(), &[2, 2]);
        assert_eq!(r.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
        assert_eq!(r.ty().unwrap(), ElementType::F32);
        assert!(l.reshape(&[3, 2]).is_err());
        assert!(l.to_vec::<i32>().is_err());
    }

    #[test]
    fn convert_to_f32() {
        let l = Literal::vec1(&[1i32, -2, 3]);
        let f = l.convert(PrimitiveType::F32).unwrap();
        assert_eq!(f.to_vec::<f32>().unwrap(), vec![1.0, -2.0, 3.0]);
    }

    #[test]
    fn device_paths_error() {
        assert!(PjRtClient::cpu().is_err());
        assert!(HloModuleProto::from_text_file("x.hlo.txt").is_err());
    }
}
