//! Property-based tests over the coordinator's invariants.
//!
//! The offline vendor set has no proptest crate, so this file uses a
//! small seeded-fuzz harness (`props!`): each property runs across many
//! PCG32-seeded random cases and reports the failing seed, which makes
//! every failure reproducible by construction.

use quantune::quant::{
    fake_quant_weights, general_space, vta_space, ALL_SCHEMES, BitWidth, CalibCount,
    Clipping, ConfigSpace, Granularity, Histogram, QuantConfig, Scheme, SpaceRef,
    VtaConfig,
};
use quantune::search::{
    crowding_distance, dominates, non_dominated_sort, promotion_count, run_racing,
    run_search, rung_fractions, Components, GeneticSearch, GridSearch, ParetoSearch,
    ParetoTrace, RacingOptions, RandomSearch, SearchAlgo, SuccessiveHalving, Trial,
    XgbSearch,
};
use quantune::util::{Json, Pcg32, Pool};
use quantune::vta::rshift_round;
use quantune::xgb::{XgbModel, XgbParams};

/// Run `f` across `n` seeded cases.
fn props(n: u64, mut f: impl FnMut(&mut Pcg32)) {
    for seed in 0..n {
        let mut rng = Pcg32::seeded(seed * 7919 + 13);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            f(&mut rng)
        }));
        if let Err(e) = result {
            eprintln!("property failed at seed {seed}");
            std::panic::resume_unwind(e);
        }
    }
}

// ---------------------------------------------------------------------------
// quantization math
// ---------------------------------------------------------------------------

#[test]
fn prop_fake_quant_error_bounded_all_schemes() {
    props(200, |rng| {
        let lo = -rng.range_f32(0.01, 20.0);
        let hi = rng.range_f32(0.01, 20.0);
        for scheme in ALL_SCHEMES {
            let p = scheme.params_from_range(lo, hi);
            let (flo, fhi) = p.float_range();
            for _ in 0..32 {
                let x = rng.range_f32(lo, hi);
                let err = (p.fake_quant(x) - x).abs();
                // inside the representable interval the error is pure
                // rounding (half a step); outside it is saturation --
                // distance to the nearest representable value plus the
                // final rounding (pow2 rounds its scale down by up to
                // sqrt(2), so saturation can be substantial by design)
                let sat = (flo - x).max(x - fhi).max(0.0);
                let bound = p.scale * 0.5 + sat;
                assert!(
                    err <= bound + 1e-5,
                    "{scheme}: x={x} err={err} scale={} range=({lo},{hi})",
                    p.scale
                );
            }
        }
    });
}

#[test]
fn prop_fake_quant_idempotent() {
    // quantizing an already-quantized value must be a fixed point
    props(100, |rng| {
        let scheme = ALL_SCHEMES[rng.below(4)];
        let p = scheme.params_from_range(-rng.range_f32(0.1, 8.0), rng.range_f32(0.1, 8.0));
        for _ in 0..16 {
            let x = rng.range_f32(-10.0, 10.0);
            let once = p.fake_quant(x);
            let twice = p.fake_quant(once);
            assert!(
                (once - twice).abs() < 1e-6,
                "{scheme}: fq not idempotent at {x}: {once} -> {twice}"
            );
        }
    });
}

#[test]
fn prop_weight_fake_quant_preserves_shape_and_bounds() {
    props(60, |rng| {
        let c = 1 + rng.below(9);
        let k = 1 + rng.below(4);
        let shape = vec![k, k, 1 + rng.below(8), c];
        let n: usize = shape.iter().product();
        let w = quantune::ir::Tensor {
            shape: shape.clone(),
            data: (0..n).map(|_| rng.normal() * rng.range_f32(0.01, 3.0)).collect(),
        };
        let scheme = ALL_SCHEMES[rng.below(4)];
        for gran in [Granularity::Tensor, Granularity::Channel] {
            let fq = fake_quant_weights(&w, scheme, gran);
            assert_eq!(fq.shape, shape);
            let (lo, hi) = w.range();
            let slack = (hi - lo).max(1e-3);
            let (flo, fhi) = fq.range();
            assert!(flo >= lo - slack && fhi <= hi + slack);
        }
    });
}

#[test]
fn prop_histogram_count_conserved_under_growth() {
    props(60, |rng| {
        let mut h = Histogram::new();
        let mut total = 0u64;
        for _ in 0..1 + rng.below(6) {
            let scale = rng.range_f32(0.01, 100.0);
            let n = 16 + rng.below(500);
            let xs: Vec<f32> = (0..n).map(|_| rng.normal() * scale).collect();
            total += n as u64;
            h.update(&xs);
        }
        assert_eq!(h.count, total);
        assert_eq!(h.bins.iter().sum::<u64>(), total);
        let t = h.kl_threshold();
        assert!(t > 0.0 && t.is_finite());
        let (lo, hi) = h.kl_clipped_range();
        let (rlo, rhi) = h.range();
        assert!(lo >= rlo - 1e-6 && hi <= rhi + 1e-6, "clip must shrink the range");
    });
}

#[test]
fn prop_aciq_threshold_near_bruteforce_scan_optimum() {
    // the analytical alpha* must land near the minimum of a dense
    // threshold scan of the *empirical* expected MSE, for every scheme
    // and integer width: the closed form assumes an exact Laplace /
    // Gaussian and uniform rounding noise, so "near" is a small constant
    // factor, not equality. Pow2 rounds its scale down by up to sqrt(2)
    // (4x in noise power), which the closed form does not model, so its
    // tolerance is wider.
    props(8, |rng| {
        let scale = rng.range_f32(0.05, 5.0);
        let laplace = rng.chance(0.5);
        let mut h = Histogram::new();
        for _ in 0..30 {
            let xs: Vec<f32> = (0..2048)
                .map(|_| {
                    if laplace {
                        let u = rng.range_f32(-0.4999, 0.4999);
                        -u.signum() * (1.0 - 2.0 * u.abs()).ln() * scale
                    } else {
                        rng.normal() * scale
                    }
                })
                .collect();
            h.update(&xs);
        }
        let bin_w = f64::from(h.limit) / h.bins.len() as f64;
        for scheme in ALL_SCHEMES {
            for (width, bits) in [(BitWidth::Int4, 4u32), (BitWidth::Int8, 8)] {
                // empirical expected MSE of clipping at alpha, straight
                // from the |x| histogram through the real quantizer
                let mse = |alpha: f32| -> f64 {
                    let p = scheme.params_for(-alpha, alpha, width);
                    let mut acc = 0.0f64;
                    for (i, &c) in h.bins.iter().enumerate() {
                        if c > 0 {
                            let x = ((i as f64 + 0.5) * bin_w) as f32;
                            let e = f64::from(p.fake_quant(x) - x);
                            acc += c as f64 * e * e;
                        }
                    }
                    acc / h.count as f64
                };
                let scan_min = (1..=160)
                    .map(|k| mse(h.limit * k as f32 / 160.0))
                    .fold(f64::INFINITY, f64::min);
                let t = h.aciq_threshold(bits).expect("non-degenerate stream");
                assert!(t > 0.0 && t <= h.limit);
                let factor = if scheme == Scheme::Pow2 { 8.0 } else { 3.0 };
                assert!(
                    mse(t) <= factor * scan_min + 1e-12,
                    "{scheme}/{width} {}: aciq alpha={t} mse={} vs scan min {}",
                    if laplace { "laplace" } else { "gauss" },
                    mse(t),
                    scan_min,
                );
            }
        }
    });
}

// ---------------------------------------------------------------------------
// configuration space
// ---------------------------------------------------------------------------

#[test]
fn prop_legacy_indices_decode_with_the_pre_extension_formula() {
    // any index below LEGACY_SPACE_SIZE must decode to exactly what the
    // paper's original 96-config nested order produced -- no aciq, no
    // bias correction, and the same positional arithmetic -- so stored
    // trial records keep their meaning under the grown space
    props(200, |rng| {
        let i = rng.below(QuantConfig::LEGACY_SPACE_SIZE);
        let cfg = QuantConfig::from_index(i).unwrap();
        assert!(!cfg.bias_correct, "legacy index {i}");
        let kl = match cfg.clip {
            Clipping::Max => 0,
            Clipping::Kl => 1,
            Clipping::Aciq => panic!("legacy index {i} decoded to aciq"),
        };
        let s = ALL_SCHEMES.iter().position(|x| x == &cfg.scheme).unwrap();
        let gran = (cfg.gran == Granularity::Channel) as usize;
        let legacy_index = (((cfg.calib.index() * 4 + s) * 2 + kl) * 2 + gran) * 2
            + cfg.mixed as usize;
        assert_eq!(legacy_index, i);
    });
}

#[test]
fn prop_genome_decode_always_valid() {
    props(200, |rng| {
        let mut bits = [false; 9];
        for b in &mut bits {
            *b = rng.chance(0.5);
        }
        let cfg = QuantConfig::from_genome(&bits);
        assert!(cfg.index() < QuantConfig::SPACE_SIZE);
        // decoding the canonical genome of the decoded config round-trips
        let again = QuantConfig::from_genome(&cfg.to_genome());
        assert_eq!(cfg, again);
    });
}

#[test]
fn prop_one_hot_is_injective() {
    let mut seen = std::collections::HashMap::new();
    for cfg in QuantConfig::space() {
        let key: Vec<u8> = cfg.one_hot().iter().map(|&x| x as u8).collect();
        assert!(
            seen.insert(key, cfg).is_none(),
            "one-hot collision at {cfg}"
        );
    }
    for cfg in VtaConfig::space() {
        assert!(cfg.index() < VtaConfig::SPACE_SIZE);
        assert_eq!(VtaConfig::from_index(cfg.index()).unwrap(), cfg);
    }
}

#[test]
fn prop_space_decode_total_on_random_genomes() {
    // any random bit string decodes to a valid index of the space, and
    // re-encoding the decoded index is a fixed point of decode
    let spaces = [general_space(), vta_space()];
    props(200, |rng| {
        for space in &spaces {
            let bits: Vec<bool> =
                (0..space.genome_bits()).map(|_| rng.chance(0.5)).collect();
            let i = space.decode(&bits);
            assert!(i < space.size(), "{}", space.tag());
            let canon = space.encode(i).unwrap();
            assert_eq!(space.decode(&canon), i, "{}", space.tag());
        }
    });
}

/// Layer-wise spaces over width menus of radix 2, 3, and 4, built once
/// on the synthetic model (the properties below fuzz genomes, not the
/// construction).
fn radix_spaces() -> Vec<SpaceRef> {
    let model = quantune::zoo::synthetic_model(8, 4, 4, 3).unwrap();
    let calib = quantune::data::synthetic_dataset(32, 8, 8, 4, 4, 5);
    let cache = quantune::calib::calibrate(
        &model,
        &calib,
        CalibCount::C1,
        &quantune::calib::CalibBackend::Interp,
        1,
    )
    .unwrap();
    let base = QuantConfig {
        calib: CalibCount::C1,
        scheme: Scheme::Symmetric,
        clip: Clipping::Max,
        gran: Granularity::Tensor,
        mixed: false,
        bias_correct: false,
    };
    [
        &[BitWidth::Int8][..],                                  // radix 2 (+fp32)
        &[BitWidth::Int4, BitWidth::Int8][..],                  // radix 3
        &[BitWidth::Int4, BitWidth::Int8, BitWidth::Int16][..], // radix 4
    ]
    .into_iter()
    .map(|menu| -> SpaceRef {
        std::sync::Arc::new(
            quantune::quant::LayerwiseSpace::rank(
                &model.name,
                &model.graph,
                model.weights_map(),
                &cache.hists,
                base,
                3,
                menu,
            )
            .unwrap(),
        )
    })
    .collect()
}

#[test]
fn prop_radix_genome_roundtrips_and_decode_total() {
    let spaces = radix_spaces();
    // exhaustive roundtrip per radix
    for space in &spaces {
        for i in 0..space.size() {
            assert_eq!(space.decode(&space.encode(i).unwrap()), i, "{}", space.tag());
        }
    }
    // random genomes always land inside the space (digit fields wrap),
    // truncated genomes read missing bits as zero
    props(200, |rng| {
        for space in &spaces {
            let bits: Vec<bool> =
                (0..space.genome_bits()).map(|_| rng.chance(0.5)).collect();
            let i = space.decode(&bits);
            assert!(i < space.size(), "{}", space.tag());
            assert_eq!(space.decode(&space.encode(i).unwrap()), i, "{}", space.tag());
            let cut = rng.below(bits.len() + 1);
            let j = space.decode(&bits[..cut]);
            assert!(j < space.size(), "{} truncated", space.tag());
        }
    });
}

#[test]
fn prop_width_grids_bound_roundtrip_error() {
    // quantize -> dequantize on every (scheme, width) grid stays within
    // half a step inside the representable interval, saturates outside
    props(100, |rng| {
        let lo = -rng.range_f32(0.01, 20.0);
        let hi = rng.range_f32(0.01, 20.0);
        for scheme in ALL_SCHEMES {
            for width in [BitWidth::Int4, BitWidth::Int8, BitWidth::Int16] {
                let p = scheme.params_for(lo, hi, width);
                let (flo, fhi) = p.float_range();
                for _ in 0..16 {
                    let x = rng.range_f32(lo, hi);
                    let sat = (flo - x).max(x - fhi).max(0.0);
                    let err = (p.fake_quant(x) - x).abs();
                    assert!(
                        err <= p.scale * 0.5 + sat + 1e-5,
                        "{scheme}/{width}: x={x} err={err} scale={}",
                        p.scale
                    );
                }
            }
        }
    });
}

// ---------------------------------------------------------------------------
// search invariants
// ---------------------------------------------------------------------------

#[test]
fn prop_search_respects_budget_and_returns_history_best() {
    let size = QuantConfig::SPACE_SIZE;
    props(40, |rng| {
        let seed = rng.next_u64();
        let budget = 1 + rng.below(size);
        let table: Vec<f64> = (0..size).map(|_| rng.f64()).collect();
        let algos: Vec<Box<dyn SearchAlgo>> = vec![
            Box::new(RandomSearch::new(size, seed)),
            Box::new(GridSearch::new(size, seed)),
            Box::new(GeneticSearch::new(general_space(), seed)),
            Box::new(XgbSearch::new(
                (0..size)
                    .map(|i| QuantConfig::from_index(i).unwrap().one_hot())
                    .collect(),
                seed,
            )),
        ];
        for mut algo in algos {
            let trace =
                run_search(algo.as_mut(), budget, |i| Ok(table[i])).unwrap();
            assert!(trace.trials.len() <= budget);
            let max = trace
                .trials
                .iter()
                .map(|t| t.score)
                .fold(f64::NEG_INFINITY, f64::max);
            assert_eq!(trace.best_score, max, "{}", trace.algo);
            assert!(trace.trials.iter().all(|t| t.config < size));
        }
    });
}

#[test]
fn prop_random_and_grid_never_repeat() {
    props(40, |rng| {
        let seed = rng.next_u64();
        for mut algo in [
            Box::new(RandomSearch::new(96, seed)) as Box<dyn SearchAlgo>,
            Box::new(GridSearch::new(96, seed)),
        ] {
            let mut seen = std::collections::HashSet::new();
            let mut hist: Vec<Trial> = Vec::new();
            while let Some(i) = algo.propose(&hist) {
                assert!(seen.insert(i), "{} repeated {i}", algo.name());
                hist.push(Trial::of(i, 0.0));
                if hist.len() > 96 {
                    panic!("{} exceeded the space", algo.name());
                }
            }
            assert_eq!(seen.len(), 96);
        }
    });
}

#[test]
fn prop_xgb_never_reproposes_explored() {
    props(20, |rng| {
        let seed = rng.next_u64();
        let feats: Vec<Vec<f32>> =
            (0..96).map(|i| QuantConfig::from_index(i).unwrap().one_hot()).collect();
        let mut algo = XgbSearch::new(feats, seed);
        let mut hist: Vec<Trial> = Vec::new();
        for _ in 0..30 {
            let i = algo.propose(&hist).unwrap();
            assert!(
                !hist.iter().any(|t| t.config == i),
                "xgb re-proposed explored config {i}"
            );
            hist.push(Trial::of(i, rng.f64()));
        }
    });
}

// ---------------------------------------------------------------------------
// VTA arithmetic
// ---------------------------------------------------------------------------

#[test]
fn prop_rshift_round_is_nearest() {
    // the rounded shift must land within half a step of the true
    // quotient: |got * 2^s - v| <= 2^(s-1)  (exact halves may go either
    // way -- the hardware rounds toward +inf, floats round to even)
    props(200, |rng| {
        let v = rng.next_u32() as i64 - (u32::MAX / 2) as i64;
        let shift = rng.below(20) as i32;
        let got = rshift_round(v, shift);
        let step = 1i64 << shift;
        let err = (got * step - v).abs();
        assert!(
            err <= step / 2,
            "v={v} shift={shift}: got {got}, reconstruction error {err} > {}",
            step / 2
        );
    });
}

#[test]
fn prop_rshift_round_monotone() {
    props(100, |rng| {
        let shift = rng.below(16) as i32;
        let a = rng.next_u32() as i64 % 100_000;
        let b = a + rng.below(1000) as i64;
        assert!(rshift_round(a, shift) <= rshift_round(b, shift));
    });
}

// ---------------------------------------------------------------------------
// XGBoost
// ---------------------------------------------------------------------------

#[test]
fn prop_xgb_fits_within_label_range() {
    props(30, |rng| {
        let n = 10 + rng.below(60);
        let d = 1 + rng.below(6);
        let x: Vec<Vec<f32>> =
            (0..n).map(|_| (0..d).map(|_| rng.f32()).collect()).collect();
        let y: Vec<f32> = (0..n).map(|_| rng.f32()).collect();
        let m = XgbModel::fit(&x, &y, XgbParams::default()).unwrap();
        let (lo, hi) = y
            .iter()
            .fold((f32::INFINITY, f32::NEG_INFINITY), |(l, h), &v| (l.min(v), h.max(v)));
        let span = (hi - lo).max(0.1);
        for row in &x {
            let p = m.predict(row);
            assert!(
                p >= lo - span && p <= hi + span,
                "prediction {p} far outside label range [{lo},{hi}]"
            );
        }
        // importance is a distribution (or all-zero)
        let imp = m.feature_importance();
        let s: f64 = imp.iter().sum();
        assert!(s == 0.0 || (s - 1.0).abs() < 1e-9);
    });
}

// ---------------------------------------------------------------------------
// worker pool (util::pool)
// ---------------------------------------------------------------------------

#[test]
fn prop_pool_processes_each_item_exactly_once_in_order() {
    use std::sync::atomic::{AtomicUsize, Ordering};
    props(25, |rng| {
        let n = rng.below(120);
        let threads = 1 + rng.below(9);
        let counts: Vec<AtomicUsize> = (0..n).map(|_| AtomicUsize::new(0)).collect();
        let out = Pool::new(threads)
            .run(n, |i| {
                counts[i].fetch_add(1, Ordering::Relaxed);
                i * 3
            })
            .unwrap();
        // output order matches input order...
        assert_eq!(out, (0..n).map(|i| i * 3).collect::<Vec<_>>());
        // ...and every item ran exactly once
        assert!(counts.iter().all(|c| c.load(Ordering::Relaxed) == 1));
    });
}

#[test]
fn prop_pool_zero_items_is_empty_ok() {
    for threads in [1, 2, 8] {
        assert!(Pool::new(threads).run(0, |i| i).unwrap().is_empty());
        let none: Vec<u8> = Vec::new();
        assert!(Pool::new(threads).map(&none, |x| *x).unwrap().is_empty());
    }
}

#[test]
fn prop_pool_worker_panic_surfaces_as_error() {
    props(10, |rng| {
        let threads = 1 + rng.below(8);
        let bad = rng.below(24);
        let err = Pool::new(threads)
            .run(24, |i| {
                assert!(i != bad, "injected failure");
                i
            })
            .unwrap_err();
        assert!(
            format!("{err}").contains("panicked"),
            "threads {threads}: unexpected error {err}"
        );
    });
}

// ---------------------------------------------------------------------------
// util
// ---------------------------------------------------------------------------

#[test]
fn prop_json_roundtrip_random_values() {
    props(100, |rng| {
        fn gen(rng: &mut Pcg32, depth: usize) -> Json {
            match if depth > 2 { rng.below(4) } else { rng.below(6) } {
                0 => Json::Null,
                1 => Json::Bool(rng.chance(0.5)),
                2 => Json::Num((rng.next_u32() as f64 / 1000.0) - 1000.0),
                3 => Json::Str(format!("s{}_\"q\"\n", rng.below(1000))),
                4 => Json::Arr((0..rng.below(5)).map(|_| gen(rng, depth + 1)).collect()),
                _ => Json::Obj(
                    (0..rng.below(5))
                        .map(|i| (format!("k{i}"), gen(rng, depth + 1)))
                        .collect(),
                ),
            }
        }
        let v = gen(rng, 0);
        assert_eq!(Json::parse(&v.dump()).unwrap(), v);
        assert_eq!(Json::parse(&v.pretty()).unwrap(), v);
    });
}

#[test]
fn prop_calib_count_monotone() {
    for (a, b) in [(CalibCount::C1, CalibCount::C64), (CalibCount::C64, CalibCount::C512)]
    {
        assert!(a.images() < b.images());
        assert!(a.paper_images() < b.paper_images());
    }
    assert_eq!(Clipping::Max, Clipping::Max);
    assert_ne!(Scheme::Pow2, Scheme::Symmetric);
}

// ---------------------------------------------------------------------------
// Pareto-front machinery (NSGA-II)
// ---------------------------------------------------------------------------

/// Random objective vector; `nan_p` is the chance of poisoning each
/// component with NaN (NaN accuracy models a budget-rejected config).
fn random_components(rng: &mut Pcg32, nan_p: f64) -> Components {
    let v = |rng: &mut Pcg32, lo: f32, hi: f32| {
        if rng.chance(nan_p) {
            f64::NAN
        } else {
            rng.range_f32(lo, hi) as f64
        }
    };
    Components {
        accuracy: v(rng, 0.0, 1.0),
        latency_ms: v(rng, 0.1, 20.0),
        size_bytes: v(rng, 100.0, 10_000.0),
    }
}

#[test]
fn prop_non_dominated_sort_partitions_and_front0_is_undominated() {
    props(120, |rng| {
        let n = 1 + rng.below(24);
        let pts: Vec<Components> =
            (0..n).map(|_| random_components(rng, 0.15)).collect();
        let fronts = non_dominated_sort(&pts);
        // partition: every index appears exactly once
        let mut all: Vec<usize> = fronts.concat();
        all.sort_unstable();
        assert_eq!(all, (0..n).collect::<Vec<_>>());
        // no front-0 member is dominated by ANY population member
        for &i in &fronts[0] {
            for (j, q) in pts.iter().enumerate() {
                assert!(
                    i == j || !dominates(q, &pts[i]),
                    "front-0 point {i} dominated by {j}"
                );
            }
        }
        // layering: every later-front member is dominated by someone in
        // the previous front
        for k in 1..fronts.len() {
            for &i in &fronts[k] {
                assert!(
                    fronts[k - 1].iter().any(|&j| dominates(&pts[j], &pts[i])),
                    "front-{k} point {i} not dominated by front {}",
                    k - 1
                );
            }
        }
        // a NaN-accuracy point never shares a front with a measured one
        // unless its whole front is NaN (measured points dominate NaN)
        for front in &fronts {
            let nan = front.iter().filter(|&&i| pts[i].accuracy.is_nan()).count();
            assert!(
                nan == 0 || nan == front.len(),
                "mixed NaN/measured front: {front:?}"
            );
        }
    });
}

#[test]
fn prop_crowding_distance_deterministic_nonnegative_boundaries_inf() {
    props(120, |rng| {
        let n = 1 + rng.below(16);
        let pts: Vec<Components> =
            (0..n).map(|_| random_components(rng, 0.1)).collect();
        let fronts = non_dominated_sort(&pts);
        for front in &fronts {
            let d1 = crowding_distance(&pts, front);
            let d2 = crowding_distance(&pts, front);
            assert_eq!(d1, d2, "crowding must be deterministic (tie-break by index)");
            assert_eq!(d1.len(), front.len());
            assert!(d1.iter().all(|&x| x >= 0.0), "{d1:?}");
            if front.len() <= 2 {
                assert!(d1.iter().all(|x| x.is_infinite()));
            } else {
                // at least the two per-axis boundary members are infinite
                assert!(d1.iter().filter(|x| x.is_infinite()).count() >= 2, "{d1:?}");
            }
        }
    });
}

#[test]
fn prop_pareto_trace_front_never_dominated_and_hv_monotone() {
    props(60, |rng| {
        let n = 1 + rng.below(20);
        let trials: Vec<Trial> = (0..n)
            .map(|i| {
                let c = random_components(rng, 0.1);
                Trial::scored(i, c.accuracy, c)
            })
            .collect();
        let trace = ParetoTrace::from_trials("nsga2", &trials);
        for f in &trace.front {
            let fc = f.components.unwrap();
            assert!(!fc.accuracy.is_nan(), "NaN accuracy entered the front");
            for t in &trials {
                assert!(!dominates(&t.components.unwrap(), &fc));
            }
        }
        // hypervolume is monotone under adding points
        let reference =
            Components { accuracy: -0.1, latency_ms: 25.0, size_bytes: 20_000.0 };
        let half = ParetoTrace::from_trials("nsga2", &trials[..n.div_ceil(2)]);
        // relative slack: hypervolumes reach ~5e5 here, where absolute
        // 1e-9 leaves no room for summation rounding between the two
        // independently-computed fronts
        let full_hv = trace.hypervolume(reference);
        assert!(
            half.hypervolume(reference) <= full_hv + 1e-9 * full_hv.max(1.0),
            "adding points must not shrink the hypervolume"
        );
    });
}

#[test]
fn prop_nsga2_proposals_always_in_space_and_deterministic() {
    for space in [general_space(), vta_space()] {
        props(12, |rng| {
            let seed = rng.next_u64();
            let run = || {
                let mut s = ParetoSearch::new(space.clone(), seed);
                run_search(&mut s, 30, |i| {
                    assert!(i < space.size(), "nsga2 proposed {i} outside the space");
                    let acc = (i % 13) as f64 / 13.0;
                    Ok((
                        acc,
                        Components {
                            accuracy: acc,
                            latency_ms: 1.0 + (i % 5) as f64,
                            size_bytes: 100.0 + (i % 7) as f64,
                        },
                    ))
                })
                .unwrap()
            };
            let (a, b) = (run(), run());
            let cfgs =
                |t: &quantune::search::SearchTrace| -> Vec<usize> {
                    t.trials.iter().map(|x| x.config).collect()
                };
            assert_eq!(cfgs(&a), cfgs(&b), "same seed must replay identically");
        });
    }
}

// ---------------------------------------------------------------------------
// multi-fidelity racing: rung arithmetic
// ---------------------------------------------------------------------------

#[test]
fn prop_rung_ladder_well_formed() {
    // for any (eta, fidelity_min): the ladder is never empty, ends at
    // full fidelity, never dips below fidelity_min, and consecutive
    // rungs differ by exactly the promotion factor
    props(300, |rng| {
        let eta = 2 + rng.below(7);
        let fidelity_min = rng.range_f32(1e-4, 1.0) as f64;
        let rungs = rung_fractions(fidelity_min, eta);
        assert!(!rungs.is_empty(), "eta {eta} min {fidelity_min}: empty ladder");
        assert_eq!(*rungs.last().unwrap(), 1.0, "ladder must end at full fidelity");
        for r in &rungs {
            assert!(
                *r >= fidelity_min && *r <= 1.0,
                "rung {r} outside [{fidelity_min}, 1]"
            );
        }
        for w in rungs.windows(2) {
            let ratio = w[1] / w[0];
            assert!(
                (ratio - eta as f64).abs() < 1e-9 * eta as f64,
                "consecutive rungs {w:?} are not an eta={eta} step"
            );
        }
        // one more division would cross fidelity_min (the ladder is the
        // longest admissible one)
        assert!(rungs[0] / eta as f64 < fidelity_min, "ladder too short: {rungs:?}");
    });
}

#[test]
fn prop_promotion_counts_monotone_and_never_empty() {
    props(300, |rng| {
        let eta = 2 + rng.below(7);
        let mut prev = 0usize;
        for n in 1..=64usize {
            let k = promotion_count(n, eta);
            assert!(k >= 1, "n {n} eta {eta}: a rung must promote someone");
            assert!(k <= n, "n {n} eta {eta}: promoted {k} > members");
            assert!(k >= prev, "promotion counts must be monotone in n");
            prev = k;
        }
        // a full generation halves down to exactly one survivor: each
        // promotion divides by exactly eta, one division per rung step
        let fidelity_min = rng.range_f32(1e-3, 1.0) as f64;
        let sh = SuccessiveHalving::new(RacingOptions { eta, fidelity_min }).unwrap();
        let mut n = sh.generation_size();
        assert_eq!(n, eta.pow((sh.rungs().len() - 1) as u32));
        for _ in 1..sh.rungs().len() {
            n = promotion_count(n, eta);
        }
        assert_eq!(n, 1, "a full generation must race down to one survivor");
    });
}

#[test]
fn prop_racing_budget_and_cost_never_exceeded() {
    // for any (space, budget, ladder): base-rung proposals never exceed
    // the budget, every trial sits on a ladder rung, the total cost is
    // bounded by the trial count, and a winner was measured at full
    // fidelity
    props(60, |rng| {
        let eta = 2 + rng.below(3);
        let fidelity_min = [1.0, 0.5, 0.25, 1.0 / 16.0][rng.below(4)];
        let opts = RacingOptions { eta, fidelity_min };
        let sh = SuccessiveHalving::new(opts).unwrap();
        let size = 1 + rng.below(96);
        let budget = 1 + rng.below(40);
        let mut algo = RandomSearch::new(size, rng.next_u64());
        let trace = run_racing(&mut algo, budget, opts, |i, fid| {
            Ok((i % 17) as f64 / 17.0 + 0.001 * fid.value())
        })
        .unwrap();
        let base_fid = sh.rungs()[0].value();
        let base = trace.trials.iter().filter(|t| t.fidelity == base_fid).count();
        assert!(base <= budget, "{base} base-rung trials > budget {budget}");
        for t in &trace.trials {
            assert!(
                sh.rungs().iter().any(|r| r.value() == t.fidelity),
                "trial fidelity {} is not a ladder rung",
                t.fidelity
            );
            assert!(t.cost <= t.fidelity, "cost {} > fidelity {}", t.cost, t.fidelity);
        }
        assert!(trace.total_cost() <= trace.trials.len() as f64 + 1e-9);
        assert!(trace.trials.iter().any(|t| t.fidelity >= 1.0));
        assert!(trace.best_score.is_finite());
    });
}
