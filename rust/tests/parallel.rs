//! Parity and determinism guarantees of the parallel evaluation engine.
//!
//! - row-tiled GEMM is bit-exact against the serial kernel at 1/2/4/8
//!   threads, including ragged shapes (rows < threads, empty operands);
//! - the batch-parallel `InterpEvaluator` measures bit-identical Top-1
//!   at every thread count, including an empty eval split;
//! - all six search algorithms (including the NSGA-II Pareto search and
//!   its `ParetoTrace` frontier view) produce byte-identical traces for
//!   the same seed at 1 vs 8 worker threads.
//!
//! Everything runs on synthetic models/datasets (no artifacts needed),
//! so this suite is always active.

use quantune::coordinator::{
    self, InterpEvaluator, ObjectiveWeights, Quantune, SharedEvaluator,
};
use quantune::data::synthetic_dataset;
use quantune::interp::gemm::{gemm_f32, gemm_f32_tiled, gemm_i32, gemm_i32_tiled};
use quantune::quant::{general_space, vta_space, ConfigSpace};
use quantune::search::{run_search, SearchTrace, TransferRecord};
use quantune::util::{Pcg32, Pool};
use quantune::zoo::synthetic_model;

const THREAD_COUNTS: [usize; 4] = [1, 2, 4, 8];

fn random_mat(rng: &mut Pcg32, len: usize, zero_p: f64) -> Vec<f32> {
    (0..len).map(|_| if rng.chance(zero_p) { 0.0 } else { rng.normal() }).collect()
}

#[test]
fn gemm_f32_tiled_matches_serial_at_all_thread_counts() {
    // ragged on purpose: rows < threads, rows not divisible by threads,
    // k not divisible by the 4-unroll, and empty operands
    let shapes = [
        (0usize, 5usize, 4usize),
        (1, 7, 3),
        (3, 9, 8),
        (5, 4, 1),
        (17, 13, 6),
        (64, 33, 20),
    ];
    let mut rng = Pcg32::seeded(11);
    for &(m, k, n) in &shapes {
        let a = random_mat(&mut rng, m * k, 0.3);
        let b = random_mat(&mut rng, k * n, 0.0);
        // non-zero initial C exercises the accumulate semantics
        let mut base = vec![0.25f32; m * n];
        gemm_f32_tiled(m, k, n, &a, &b, &mut base, 1);
        for &threads in &THREAD_COUNTS {
            let mut c = vec![0.25f32; m * n];
            gemm_f32_tiled(m, k, n, &a, &b, &mut c, threads);
            for (i, (&x, &y)) in c.iter().zip(&base).enumerate() {
                assert!(
                    (x - y).abs() <= 1e-6,
                    "({m},{k},{n}) threads {threads} elem {i}: {x} vs {y}"
                );
                assert_eq!(
                    x.to_bits(),
                    y.to_bits(),
                    "row tiling must be bit-exact, not just close"
                );
            }
        }
    }
}

#[test]
fn gemm_i32_tiled_matches_serial_at_all_thread_counts() {
    let (m, k, n) = (23, 11, 9);
    let mut rng = Pcg32::seeded(13);
    let a: Vec<i32> = (0..m * k).map(|_| rng.below(256) as i32 - 128).collect();
    let b: Vec<i32> = (0..k * n).map(|_| rng.below(256) as i32 - 128).collect();
    let mut base = vec![7i32; m * n];
    gemm_i32_tiled(m, k, n, &a, &b, &mut base, 1);
    for &threads in &THREAD_COUNTS {
        let mut c = vec![7i32; m * n];
        gemm_i32_tiled(m, k, n, &a, &b, &mut c, threads);
        assert_eq!(c, base, "{threads} threads");
    }
}

#[test]
fn gemm_auto_path_matches_pinned_serial() {
    // 2.6M MACs: above the auto-parallelization threshold, so this
    // exercises whatever the environment's default thread count is
    let (m, k, n) = (512, 64, 80);
    let mut rng = Pcg32::seeded(17);
    let a = random_mat(&mut rng, m * k, 0.5);
    let b = random_mat(&mut rng, k * n, 0.0);
    let mut serial = vec![0.0f32; m * n];
    gemm_f32_tiled(m, k, n, &a, &b, &mut serial, 1);
    let mut auto = vec![0.0f32; m * n];
    gemm_f32(m, k, n, &a, &b, &mut auto);
    for (x, y) in auto.iter().zip(&serial) {
        assert_eq!(x.to_bits(), y.to_bits());
    }

    let ai: Vec<i32> = (0..m * k).map(|_| rng.below(64) as i32 - 32).collect();
    let bi: Vec<i32> = (0..k * n).map(|_| rng.below(64) as i32 - 32).collect();
    let mut si = vec![0i32; m * n];
    gemm_i32_tiled(m, k, n, &ai, &bi, &mut si, 1);
    let mut pi = vec![0i32; m * n];
    gemm_i32(m, k, n, &ai, &bi, &mut pi);
    assert_eq!(pi, si);
}

#[test]
fn interp_evaluator_parity_across_thread_counts() {
    let model = synthetic_model(8, 4, 4, 3).unwrap();
    let calib = synthetic_dataset(64, 8, 8, 4, 4, 5);
    // 150 eval images over batch-64 chunks: two full + one ragged chunk
    let eval = synthetic_dataset(150, 8, 8, 4, 4, 6);
    let configs = [0usize, 17, 41, 95];
    let mut baseline = Vec::new();
    {
        let ev = InterpEvaluator::new(&model, &calib, &eval, 1).with_threads(1);
        for &c in &configs {
            baseline.push(ev.measure_shared(c).unwrap());
        }
    }
    for &threads in &THREAD_COUNTS[1..] {
        let ev = InterpEvaluator::new(&model, &calib, &eval, 1).with_threads(threads);
        for (&c, &want) in configs.iter().zip(&baseline) {
            let got = ev.measure_shared(c).unwrap();
            assert!(
                (got - want).abs() <= 1e-6,
                "config {c} at {threads} threads: {got} vs {want}"
            );
            assert_eq!(got.to_bits(), want.to_bits(), "must be bit-identical");
        }
    }
}

#[test]
fn interp_evaluator_handles_empty_eval_split() {
    let model = synthetic_model(8, 4, 4, 3).unwrap();
    let calib = synthetic_dataset(16, 8, 8, 4, 4, 5);
    let eval = synthetic_dataset(0, 8, 8, 4, 4, 6);
    for &threads in &THREAD_COUNTS {
        let ev = InterpEvaluator::new(&model, &calib, &eval, 1).with_threads(threads);
        assert_eq!(ev.measure_shared(0).unwrap(), 0.0, "{threads} threads");
    }
}

fn trace_bytes(t: &SearchTrace) -> Vec<(usize, u64, u64, u64, u64)> {
    t.trials
        .iter()
        .map(|tr| {
            let c = tr.components.unwrap_or(quantune::search::Components {
                accuracy: f64::NAN,
                latency_ms: f64::NAN,
                size_bytes: f64::NAN,
            });
            (
                tr.config,
                tr.score.to_bits(),
                c.accuracy.to_bits(),
                c.latency_ms.to_bits(),
                c.size_bytes.to_bits(),
            )
        })
        .collect()
}

/// `sweep_parallel` over a non-96 space (the 12-element VTA space) is
/// bit-identical to the serial `sweep` -- same accuracy table, same
/// persisted records in config order, same space tag.
#[test]
fn sweep_parallel_non_general_space_matches_serial() {
    let model = synthetic_model(8, 4, 4, 3).unwrap();
    let calib = synthetic_dataset(32, 8, 8, 4, 4, 5);
    let eval = synthetic_dataset(96, 8, 8, 4, 4, 6);
    let space = vta_space();
    let make_q = || Quantune {
        artifacts: std::path::PathBuf::from("."),
        calib_pool: calib.clone(),
        eval: eval.clone(),
        db: coordinator::Store::in_memory(),
        seed: 1,
        device: coordinator::DEVICES[1],
        seed_from_db: false,
    };

    let mut q_serial = make_q();
    let serial = {
        let mut ev = InterpEvaluator::new(&model, &calib, &eval, 1)
            .with_threads(1)
            .with_space(space.clone());
        q_serial
            .sweep(&model, space.as_ref(), &mut ev, false, |_, _| {})
            .unwrap()
    };
    assert_eq!(serial.len(), 12);

    for threads in [2usize, 4, 8] {
        let mut q_par = make_q();
        let ev = InterpEvaluator::new(&model, &calib, &eval, 1)
            .with_threads(1)
            .with_space(space.clone());
        let parallel = q_par
            .sweep_parallel(
                &model,
                space.as_ref(),
                &ev,
                false,
                &Pool::new(threads),
                |_, _| {},
            )
            .unwrap();
        let bits = |t: &[f64]| -> Vec<u64> { t.iter().map(|a| a.to_bits()).collect() };
        assert_eq!(bits(&serial), bits(&parallel), "{threads} threads");
        // the persisted records match the serial run in order and content
        assert_eq!(q_par.db.records().len(), q_serial.db.records().len());
        for (a, b) in q_serial.db.records().iter().zip(q_par.db.records()) {
            assert_eq!(a.model, b.model);
            assert_eq!(a.space, b.space);
            assert_eq!(a.config, b.config);
            assert_eq!(a.accuracy.to_bits(), b.accuracy.to_bits());
            // static cost components are identical too (and present)
            assert_eq!(a.latency_ms, b.latency_ms);
            assert_eq!(a.size_bytes, b.size_bytes);
            assert!(a.latency_ms.is_some() && a.size_bytes.is_some());
        }
        assert!(q_par.db.has_full_sweep(&model.name, &space.tag(), 12));
    }
}

/// Identical seed => byte-identical SearchTrace at QUANTUNE_THREADS=1 vs
/// 8 (here pinned per-evaluator rather than via the env so the test is
/// immune to process-global races). Covers all six algorithms,
/// measuring through the batch-parallel InterpEvaluator.
#[test]
fn search_traces_identical_across_thread_counts() {
    let model = synthetic_model(8, 4, 4, 3).unwrap();
    let calib = synthetic_dataset(32, 8, 8, 4, 4, 5);
    let eval = synthetic_dataset(96, 8, 8, 4, 4, 6);
    // transfer database for xgb_t: features of the full space with a
    // synthetic accuracy pattern (content is irrelevant to determinism)
    let space = general_space();
    let transfer: Vec<TransferRecord> = (0..96)
        .map(|i| TransferRecord {
            features: coordinator::features_for(&model, space.as_ref(), i).unwrap(),
            accuracy: 0.4 + (i % 7) as f32 * 0.05,
            fidelity: 1.0,
        })
        .collect();
    let seed = 20220205u64;
    let budget = 6;
    for algo in coordinator::PROPOSERS {
        let run_at = |threads: usize| -> SearchTrace {
            let ev = InterpEvaluator::new(&model, &calib, &eval, seed).with_threads(threads);
            let mut search =
                coordinator::make_algorithm(algo, &model, &space, transfer.clone(), seed)
                    .unwrap();
            run_search(search.as_mut(), budget, |cfg| ev.measure_shared(cfg)).unwrap()
        };
        let serial = run_at(1);
        let parallel = run_at(8);
        assert_eq!(serial.algo, parallel.algo);
        assert_eq!(
            trace_bytes(&serial),
            trace_bytes(&parallel),
            "{algo}: trace diverged between 1 and 8 threads"
        );
        assert_eq!(serial.best_config, parallel.best_config, "{algo}");
        assert_eq!(
            serial.best_score.to_bits(),
            parallel.best_score.to_bits(),
            "{algo}"
        );
    }
}

/// Pareto-front determinism: `search_pareto` must reproduce a
/// byte-identical scalar SearchTrace AND an identical ParetoTrace --
/// front configs, unique-evaluation count, running frontier sizes, and
/// hypervolume bits -- at 1/2/4/8 evaluator threads, for both a
/// device-priced space and the cycle-priced VTA space.
#[test]
fn pareto_trace_identical_across_thread_counts() {
    let model = synthetic_model(8, 4, 4, 3).unwrap();
    let calib = synthetic_dataset(32, 8, 8, 4, 4, 5);
    let eval = synthetic_dataset(96, 8, 8, 4, 4, 6);
    let q = Quantune {
        artifacts: std::path::PathBuf::from("."),
        calib_pool: calib.clone(),
        eval: eval.clone(),
        db: coordinator::Store::in_memory(),
        seed: 1,
        device: coordinator::DEVICES[1],
        seed_from_db: false,
    };
    let weights = ObjectiveWeights::parse("balanced").unwrap();
    let seed = 20220205u64;
    let reference = quantune::search::Components {
        accuracy: 0.0,
        latency_ms: 1e6,
        size_bytes: 1e12,
    };
    for space in [general_space(), vta_space()] {
        let run_at = |threads: usize| {
            let mut ev = InterpEvaluator::new(&model, &calib, &eval, seed)
                .with_threads(threads)
                .with_space(space.clone());
            q.search_pareto(
                &model,
                &space,
                &mut ev,
                16,
                seed,
                weights,
                coordinator::Budget::unlimited(),
            )
            .unwrap()
        };
        let (base_trace, base_pareto) = run_at(1);
        assert!(!base_pareto.front.is_empty());
        for threads in [2usize, 4, 8] {
            let (t, p) = run_at(threads);
            assert_eq!(
                trace_bytes(&base_trace),
                trace_bytes(&t),
                "{} nsga2: scalar trace diverged at {threads} threads",
                space.tag()
            );
            assert_eq!(base_pareto.front_configs(), p.front_configs());
            assert_eq!(base_pareto.evaluations, p.evaluations);
            assert_eq!(base_pareto.front_sizes, p.front_sizes);
            assert_eq!(
                base_pareto.hypervolume(reference).to_bits(),
                p.hypervolume(reference).to_bits(),
                "{} nsga2: hypervolume diverged at {threads} threads",
                space.tag()
            );
        }
    }
}

/// Multi-objective determinism: the same (seed, weights, device) must
/// reproduce a byte-identical SearchTrace -- scores AND per-component
/// breakdowns -- at 1/2/4/8 evaluator threads, for every algorithm and
/// for both a device-priced space and the cycle-priced VTA space.
#[test]
fn objective_search_traces_identical_across_thread_counts() {
    let model = synthetic_model(8, 4, 4, 3).unwrap();
    let calib = synthetic_dataset(32, 8, 8, 4, 4, 5);
    let eval = synthetic_dataset(96, 8, 8, 4, 4, 6);
    let q = Quantune {
        artifacts: std::path::PathBuf::from("."),
        calib_pool: calib.clone(),
        eval: eval.clone(),
        db: coordinator::Store::in_memory(),
        seed: 1,
        device: coordinator::DEVICES[0], // a53: strongest latency penalty
        seed_from_db: false,
    };
    let weights = ObjectiveWeights::parse("balanced").unwrap();
    let seed = 20220205u64;
    for space in [general_space(), vta_space()] {
        for algo in ["random", "genetic", "xgb"] {
            let run_at = |threads: usize| -> SearchTrace {
                let mut ev = InterpEvaluator::new(&model, &calib, &eval, seed)
                    .with_threads(threads)
                    .with_space(space.clone());
                q.search_objective(
                    &model,
                    &space,
                    algo,
                    &mut ev,
                    6,
                    seed,
                    weights,
                    coordinator::Budget::unlimited(),
                )
                .unwrap()
            };
            let base = run_at(1);
            assert!(
                base.trials.iter().all(|t| t.components.is_some()),
                "{algo}: objective trials must carry components"
            );
            for threads in [2usize, 4, 8] {
                let t = run_at(threads);
                assert_eq!(
                    trace_bytes(&base),
                    trace_bytes(&t),
                    "{} {algo}: objective trace diverged at {threads} threads",
                    space.tag()
                );
                assert_eq!(base.best_config, t.best_config);
                assert_eq!(base.best_score.to_bits(), t.best_score.to_bits());
            }
        }
    }
}
