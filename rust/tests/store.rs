//! End-to-end guarantees of the persistent trial store (PR 8):
//!
//! - concurrent writers through [`StoreWriter`] persist the exact same
//!   record sequence as a serial sweep, at any worker count;
//! - a torn tail segment (crash mid-append) recovers to the valid
//!   prefix through the `Store::open` auto-detect path and the store
//!   stays appendable;
//! - a legacy `database.json` (null accuracies, missing space tags,
//!   optional cost fields) migrates into the log with zero records
//!   lost, bit-for-bit;
//! - the watermark cursor feeding incremental XGB refits sees exactly
//!   the rows a full scan extracts, and the search-side row cache
//!   reproduces the full-extraction training set;
//! - database-seeded GA/NSGA-II populations propose the seeded configs
//!   first and degrade to the unseeded RNG stream when no seeds exist.
//!
//! Everything here runs on synthetic records -- no artifacts needed.

use std::fs;
use std::path::PathBuf;

use quantune::coordinator::{
    records_equal, Record, Store, TransferCursor, TrialStore, GENERAL_SPACE_TAG,
};
use quantune::quant::general_space;
use quantune::search::{
    GeneticSearch, ParetoSearch, SearchAlgo, TransferRecord, Trial, XgbSearch,
};
use quantune::util::Pool;

fn tmpdir(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(name);
    let _ = fs::remove_dir_all(&dir);
    dir
}

/// A varied synthetic record: every third accuracy is NaN (failed
/// measurement), optional cost fields and device present on a subset.
fn rec(i: usize) -> Record {
    Record {
        latency_ms: (i % 2 == 0).then_some(1.5 + i as f64),
        size_bytes: (i % 5 == 0).then_some(1000.0 * i as f64),
        device: (i % 4 == 0).then(|| "CPU(i7-8700)".to_string()),
        ..Record::new(
            format!("m{}", i % 3),
            GENERAL_SPACE_TAG.to_string(),
            i % 96,
            if i % 3 == 2 { f64::NAN } else { 0.4 + i as f64 / 100.0 },
            0.01 * i as f64,
        )
    }
}

#[test]
fn concurrent_writers_equal_serial_at_every_thread_count() {
    let n = 64;
    let serial_dir = tmpdir("quantune_store_stress_serial");
    let mut serial = Store::open_log(&serial_dir).unwrap();
    for i in 0..n {
        assert_eq!(serial.add(rec(i)).unwrap(), i as u64);
    }
    serial.save().unwrap();

    for threads in [1, 2, 4, 8] {
        let dir = tmpdir(&format!("quantune_store_stress_t{threads}"));
        let mut store = Store::open_log(&dir).unwrap();
        {
            let writer = store.writer();
            let results = Pool::new(threads).run(n, |i| writer.submit(i, rec(i))).unwrap();
            for r in results {
                r.unwrap();
            }
            assert_eq!(writer.finish().unwrap(), n);
        }
        assert_eq!(store.len(), n, "threads={threads}");
        for (a, b) in serial.records().iter().zip(store.records()) {
            assert!(records_equal(a, b), "threads={threads}: in-memory order diverged");
        }
        // durability: a reopen replays the identical sequence
        drop(store);
        let reopened = Store::open_log(&dir).unwrap();
        assert_eq!(reopened.len(), n, "threads={threads}");
        for (a, b) in serial.records().iter().zip(reopened.records()) {
            assert!(records_equal(a, b), "threads={threads}: replay diverged");
        }
        let _ = fs::remove_dir_all(&dir);
    }
    let _ = fs::remove_dir_all(&serial_dir);
}

#[test]
fn writer_rejects_duplicate_slots_and_gaps() {
    let mut store = Store::in_memory();
    let writer = store.writer();
    writer.submit(0, rec(0)).unwrap();
    assert!(writer.submit(0, rec(0)).is_err(), "slot 0 submitted twice");
    writer.submit(2, rec(2)).unwrap(); // parked behind the missing slot 1
    let err = writer.finish().unwrap_err().to_string();
    assert!(err.contains("missing slot 1"), "got: {err}");
    writer.submit(1, rec(1)).unwrap(); // fills the gap, drains slot 2
    assert_eq!(writer.finish().unwrap(), 3);
    drop(writer);
    assert_eq!(store.len(), 3);
    assert_eq!(store.records()[2].config, rec(2).config);
}

#[test]
fn torn_tail_recovers_through_the_autodetect_path() {
    let artifacts = tmpdir("quantune_store_torn_artifacts");
    let trials = artifacts.join("trials");
    {
        let mut store = Store::open_log(&trials).unwrap();
        for i in 0..3 {
            store.add(rec(i)).unwrap();
        }
        store.save().unwrap();
    }
    // crash mid-append: a half-written frame lands after the last record
    let seg = trials.join("segment-00000.qlog");
    let good = fs::read(&seg).unwrap();
    let mut torn = good.clone();
    torn.extend_from_slice(&[0x40, 0x00, 0x00, 0x00, 0xaa, 0xbb, 0xcc]);
    fs::write(&seg, &torn).unwrap();

    // the artifacts-level open auto-detects trials/ and recovers
    let mut store = Store::open(&artifacts).unwrap();
    assert_eq!(store.backend(), "log");
    assert_eq!(store.len(), 3, "valid prefix survives the torn frame");
    assert_eq!(fs::read(&seg).unwrap(), good, "file truncated back to the prefix");
    for (i, r) in store.records().iter().enumerate() {
        assert!(records_equal(r, &rec(i)));
    }
    // recovered store keeps its sequence numbers and stays appendable
    assert_eq!(store.add(rec(3)).unwrap(), 3);
    store.save().unwrap();
    drop(store);
    let reopened = Store::open(&artifacts).unwrap();
    assert_eq!(reopened.len(), 4);
    let _ = fs::remove_dir_all(&artifacts);
}

#[test]
fn legacy_json_migrates_into_the_log_losslessly() {
    let artifacts = tmpdir("quantune_store_migrate_artifacts");
    fs::create_dir_all(&artifacts).unwrap();
    // a hand-written legacy file: null accuracy, a record predating
    // space tags (defaults to "general"), optional fields on and off
    fs::write(
        artifacts.join("database.json"),
        r#"{"records": [
          {"model": "sqn", "space": "general", "config": 3, "accuracy": 0.71,
           "measure_secs": 0.5, "latency_ms": 2.25, "size_bytes": 123456,
           "device": "CPU(i7-8700)"},
          {"model": "sqn", "config": 9, "accuracy": null, "measure_secs": 0.4},
          {"model": "mn", "space": "vta", "config": 0, "accuracy": 0.66,
           "measure_secs": 1.25}
        ]}"#,
    )
    .unwrap();

    // without a trials/ dir, open lands on the legacy backend
    let legacy = Store::open(&artifacts).unwrap();
    assert_eq!(legacy.backend(), "json");
    assert_eq!(legacy.len(), 3);
    assert_eq!(legacy.records()[1].space, GENERAL_SPACE_TAG, "missing tag defaults");
    assert!(legacy.records()[1].accuracy.is_nan(), "null accuracy reads as NaN");
    assert_eq!(legacy.records()[0].latency_ms, Some(2.25));
    assert_eq!(legacy.records()[2].device, None);

    // replay into a log (what `quantune db migrate` does), then verify
    let trials = artifacts.join("trials");
    {
        let mut log = Store::open_log(&trials).unwrap();
        for r in legacy.records() {
            log.add(r.clone()).unwrap();
        }
        log.save().unwrap();
    }
    let migrated = Store::open(&artifacts).unwrap();
    assert_eq!(migrated.backend(), "log", "trials/ now wins the auto-detect");
    assert_eq!(migrated.len(), legacy.len());
    for (a, b) in legacy.records().iter().zip(migrated.records()) {
        assert!(records_equal(a, b), "migration must be bit-for-bit");
    }
    // the migrated store answers the same queries
    assert_eq!(
        legacy.best_for("sqn", GENERAL_SPACE_TAG),
        migrated.best_for("sqn", GENERAL_SPACE_TAG),
    );
    assert_eq!(legacy.best_for("sqn", GENERAL_SPACE_TAG), Some((3, 0.71)));
    let _ = fs::remove_dir_all(&artifacts);
}

/// Feature map used by the watermark tests: (model, config) -> a tiny
/// deterministic vector, with one model excluded to exercise skips.
fn feat(model: &str, config: usize) -> Option<Vec<f32>> {
    (model != "skipme").then(|| vec![model.len() as f32, config as f32])
}

#[test]
fn watermark_cursor_sees_exactly_what_a_full_scan_extracts() {
    let mut store = Store::in_memory();
    let mut cursor = TransferCursor::new("sqn", GENERAL_SPACE_TAG);
    assert_eq!(cursor.refresh(&store, feat), 0, "empty store, no rows");

    // batch 1: a mix of included, excluded-by-model, excluded-by-space,
    // feature-mapper-skipped, and NaN-accuracy records
    store.add(Record::new("mn".into(), GENERAL_SPACE_TAG.into(), 4, 0.61, 0.1)).unwrap();
    store.add(Record::new("sqn".into(), GENERAL_SPACE_TAG.into(), 4, 0.80, 0.1)).unwrap();
    store.add(Record::new("mn".into(), "vta".into(), 1, 0.55, 0.1)).unwrap();
    store.add(Record::new("skipme".into(), GENERAL_SPACE_TAG.into(), 2, 0.5, 0.1)).unwrap();
    store.add(Record::new("rn".into(), GENERAL_SPACE_TAG.into(), 7, f64::NAN, 0.1)).unwrap();
    assert_eq!(cursor.refresh(&store, feat), 2);
    assert_eq!(cursor.watermark(), store.next_seq());

    // batch 2: the incremental refresh consumes only the new suffix
    store.add(Record::new("mn".into(), GENERAL_SPACE_TAG.into(), 9, 0.69, 0.1)).unwrap();
    store.add(Record::new("sqn".into(), GENERAL_SPACE_TAG.into(), 9, 0.81, 0.1)).unwrap();
    assert_eq!(cursor.refresh(&store, feat), 1);
    assert_eq!(cursor.refresh(&store, feat), 0, "nothing new, nothing re-read");

    let full = store.transfer_records("sqn", GENERAL_SPACE_TAG, feat);
    let inc = cursor.records();
    assert_eq!(inc.len(), full.len());
    for (a, b) in full.iter().zip(inc) {
        assert_eq!(a.features, b.features);
        assert_eq!(a.accuracy.to_bits(), b.accuracy.to_bits());
    }
}

#[test]
fn xgb_row_cache_reproduces_the_full_extraction() {
    // 6 configs with scalar features; transfer rows fixed up front
    let space_features: Vec<Vec<f32>> = (0..6).map(|i| vec![i as f32]).collect();
    let transfer = vec![
        TransferRecord::full(vec![10.0], 0.5),
        TransferRecord::full(vec![11.0], f32::NAN), // dropped
        TransferRecord::full(vec![12.0], 0.7),
    ];
    let mut search = XgbSearch::with_transfer(space_features.clone(), transfer, 1);

    let mut history = vec![Trial::of(2, 0.62), Trial::of(5, f64::NAN)];
    search.sync_rows(&history);
    let (xs, ys) = search.training_rows();
    // full extraction: finite transfer rows, then finite history rows
    // (every row carries the trailing fidelity feature column)
    assert_eq!(xs, vec![vec![10.0, 1.0], vec![12.0, 1.0], vec![2.0, 1.0]]);
    assert_eq!(ys, vec![0.5, 0.7, 0.62]);

    // growing the history only appends the new finite rows
    history.push(Trial::of(0, 0.58));
    search.sync_rows(&history);
    let (xs, ys) = search.training_rows();
    assert_eq!(
        xs,
        vec![vec![10.0, 1.0], vec![12.0, 1.0], vec![2.0, 1.0], vec![0.0, 1.0]]
    );
    assert_eq!(ys, vec![0.5, 0.7, 0.62, 0.58]);

    // re-syncing the same history is idempotent
    search.sync_rows(&history);
    assert_eq!(search.training_rows().0.len(), 4);

    // mid-run transfer growth (a refreshed watermark cursor) lands in
    // the cache on the next sync
    search.extend_transfer([TransferRecord::full(vec![13.0], 0.9)]);
    search.sync_rows(&history);
    let (xs, ys) = search.training_rows();
    assert_eq!(xs.last().unwrap().as_slice(), [13.0, 1.0]);
    assert_eq!(ys.last().copied(), Some(0.9));
}

#[test]
fn legacy_and_new_axis_records_coexist_under_one_tag() {
    use quantune::quant::{Clipping, ConfigSpace, QuantConfig};
    // the 288-config general space keeps the legacy 96 indices in their
    // original order, so a store written before the ACIQ/bias-correct
    // axes existed keeps meaning the same configs -- and new-axis rows
    // land in the same table, ranking, and transfer extraction
    let space = general_space();
    let legacy_idx = 17;
    let cfg = QuantConfig::from_index(legacy_idx).unwrap();
    assert!(!cfg.bias_correct && cfg.clip != Clipping::Aciq);
    let new_idx = QuantConfig::LEGACY_SPACE_SIZE + 5;
    let last_idx = QuantConfig::SPACE_SIZE - 1;

    let mut store = Store::in_memory();
    store
        .add(Record::new("sqn".into(), GENERAL_SPACE_TAG.into(), legacy_idx, 0.70, 0.1))
        .unwrap();
    store
        .add(Record::new("sqn".into(), GENERAL_SPACE_TAG.into(), new_idx, 0.74, 0.1))
        .unwrap();
    store
        .add(Record::new("sqn".into(), GENERAL_SPACE_TAG.into(), last_idx, 0.72, 0.1))
        .unwrap();

    // one table spans both eras
    let table =
        store.accuracy_table("sqn", GENERAL_SPACE_TAG, QuantConfig::SPACE_SIZE);
    assert_eq!(table.len(), QuantConfig::SPACE_SIZE);
    assert_eq!(table[legacy_idx], 0.70);
    assert_eq!(table[new_idx], 0.74);
    assert_eq!(table[last_idx], 0.72);
    // best-of ranks across both eras, and the decoded best config
    // carries the new axis
    assert_eq!(store.best_for("sqn", GENERAL_SPACE_TAG), Some((new_idx, 0.74)));
    let (best_cfg, best_acc) = store.best_general("sqn").unwrap();
    assert_eq!(best_acc, 0.74);
    assert_eq!(best_cfg.index(), new_idx);
    // transfer extraction (which excludes the target model) features
    // legacy and new rows through the same space, with one consistent
    // feature dimensionality
    let feats = |_: &str, config: usize| space.features(config).ok();
    let rows = store.transfer_records("other_model", GENERAL_SPACE_TAG, feats);
    assert_eq!(rows.len(), 3);
    let dim = rows[0].features.len();
    assert!(rows.iter().all(|r| r.features.len() == dim));
}

#[test]
fn seeded_populations_propose_the_seeds_first() {
    let space = general_space();
    let seeds = [5usize, 17, 3];

    let mut ga = GeneticSearch::with_seeds(space.clone(), 7, &seeds).unwrap();
    let first: Vec<usize> = (0..3).map(|_| ga.propose(&[]).unwrap()).collect();
    assert_eq!(first, seeds, "GA proposes the database seeds first, in order");
    for _ in 3..8 {
        assert!(ga.propose(&[]).unwrap() < space.size(), "random fill stays in-space");
    }

    let mut nsga = ParetoSearch::with_seeds(space.clone(), 7, &seeds).unwrap();
    let first: Vec<usize> = (0..3).map(|_| nsga.propose(&[]).unwrap()).collect();
    assert_eq!(first, seeds, "NSGA-II warm-starts its first offspring generation");

    // an out-of-space seed is a hard error, not a silent clamp
    assert!(GeneticSearch::with_seeds(space.clone(), 7, &[space.size()]).is_err());
    assert!(ParetoSearch::with_seeds(space.clone(), 7, &[space.size()]).is_err());
}

#[test]
fn empty_seed_list_reproduces_the_unseeded_search() {
    let space = general_space();
    let mut plain = GeneticSearch::new(space.clone(), 11);
    let mut seeded = GeneticSearch::with_seeds(space.clone(), 11, &[]).unwrap();
    for _ in 0..8 {
        assert_eq!(plain.propose(&[]), seeded.propose(&[]));
    }
    let mut plain = ParetoSearch::new(space.clone(), 11);
    let mut seeded = ParetoSearch::with_seeds(space.clone(), 11, &[]).unwrap();
    for _ in 0..8 {
        assert_eq!(plain.propose(&[]), seeded.propose(&[]));
    }
}
