//! Steady-state no-packing guarantee of the prepacked weight pipeline.
//!
//! [`pack_calls`] is a process-global counter bumped by every
//! `pack_b_i8` / `pack_b_i4` invocation, so this test lives in its own
//! integration binary: cargo runs each test file as a separate process,
//! which keeps the counter free of traffic from unrelated tests running
//! concurrently. The contract under test (ROADMAP item 1 / PR 7): all
//! packing happens inside [`prepare_cached`], and repeated fake-quant
//! forwards afterwards neither repack nor drift by a single bit --
//! whether they reuse one scratch arena or bring a fresh one.

use std::collections::HashMap;
use std::sync::Arc;

use quantune::calib::{calibrate, CalibBackend};
use quantune::coordinator::{prepare_cached, WeightCache};
use quantune::data::synthetic_dataset;
use quantune::interp::kernels::pack_calls;
use quantune::interp::{InterpScratch, Interpreter};
use quantune::ir::Tensor;
use quantune::quant::{CalibCount, QuantConfig, QuantPlan};
use quantune::zoo::synthetic_model;

#[test]
fn steady_state_forwards_never_pack_and_are_bitwise_stable() {
    let model = synthetic_model(8, 4, 4, 3).unwrap();
    let calib = synthetic_dataset(16, 8, 8, 4, 4, 5);
    let eval = synthetic_dataset(32, 8, 8, 4, 4, 6);
    let cache = calibrate(&model, &calib, CalibCount::C1, &CalibBackend::Interp, 1)
        .unwrap();
    let plan: QuantPlan = QuantConfig::from_index(0).unwrap().into();
    let setup =
        prepare_cached(&model, &cache, &plan, &WeightCache::new()).unwrap();
    // config 0 is all-int8 non-mixed: one panel packed per weighted layer
    assert_eq!(setup.int_weights.len(), 3);
    assert!(
        pack_calls() >= 3,
        "prepare_cached must have packed the weight panels up front"
    );

    let weights: HashMap<String, Arc<Tensor>> = model
        .weights
        .order
        .iter()
        .cloned()
        .zip(setup.weights.iter().cloned())
        .collect();
    let interp = Interpreter::new(&model.graph, &weights)
        .with_int_weights(&setup.int_weights);
    let x = eval.batch(&(0..eval.n).collect::<Vec<_>>());

    let mut scratch = InterpScratch::for_graph(&model.graph, eval.n);
    let baseline = interp.forward_fq_with(&x, &setup.aq, &mut scratch).unwrap();
    let n0 = pack_calls();
    for pass in 0..5 {
        let logits = interp.forward_fq_with(&x, &setup.aq, &mut scratch).unwrap();
        assert_eq!(
            logits.data, baseline.data,
            "steady-state pass {pass} drifted from the first forward"
        );
    }
    assert_eq!(
        pack_calls(),
        n0,
        "steady-state forwards must not repack any weight panel"
    );

    // a fresh arena (the forward_fq convenience path) reproduces the
    // same bits: the scratch is workspace, never state
    let fresh = interp.forward_fq(&x, &setup.aq).unwrap();
    assert_eq!(fresh.data, baseline.data);
    assert_eq!(pack_calls(), n0);
}
