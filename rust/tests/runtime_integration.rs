//! Runtime integration: the python-AOT -> rust-PJRT bridge.
//!
//! These tests need `make artifacts` to have run; they skip (with a
//! notice) when the artifacts directory is absent so that pure-rust
//! development still has a green `cargo test`.

use std::path::PathBuf;

use quantune::interp::{argmax_batch, Interpreter};
use quantune::ir::Tensor;
use quantune::quant::QParams;
use quantune::runtime::{i32_to_literal, Runtime};
use quantune::util::Pcg32;
use quantune::zoo::ZooModel;

fn artifacts() -> Option<PathBuf> {
    let dir = quantune::zoo::artifacts_dir();
    if dir.join("manifest.json").exists() || dir.join("sqn_meta.json").exists() {
        Some(dir)
    } else {
        eprintln!("SKIP: no artifacts at {} (run `make artifacts`)", dir.display());
        None
    }
}

/// PJRT client, or a skip notice when the backend is unavailable (e.g.
/// the offline build links the stub `xla` crate).
fn runtime() -> Option<Runtime> {
    match Runtime::cpu() {
        Ok(rt) => Some(rt),
        Err(e) => {
            eprintln!("SKIP: PJRT unavailable ({e})");
            None
        }
    }
}

#[test]
fn pjrt_client_boots() {
    let Some(rt) = runtime() else { return };
    assert!(rt.platform().to_lowercase().contains("cpu") || rt.platform() == "Host");
}

#[test]
fn kernel_fake_quant_artifact_matches_rust() {
    let Some(dir) = artifacts() else { return };
    let path = dir.join("kernel_fake_quant.hlo.txt");
    if !path.exists() {
        eprintln!("SKIP: {} missing", path.display());
        return;
    }
    let Some(rt) = runtime() else { return };
    let exe = rt.load(&path).unwrap();

    let mut rng = Pcg32::seeded(5);
    let x = Tensor {
        shape: vec![128, 32, 32, 16],
        data: (0..128 * 32 * 32 * 16).map(|_| rng.normal() * 2.0).collect(),
    };
    let p = QParams { scale: 0.04, zero_point: 3, qmin: -128.0, qmax: 127.0 };
    let params = Tensor {
        shape: vec![5],
        data: vec![p.scale, p.zero_point as f32, p.qmin, p.qmax, 0.0],
    };
    let out = exe.run_f32(&[&x, &params]).unwrap();
    assert_eq!(out[0].shape, x.shape);
    // the Pallas kernel (via HLO) must agree bit-for-bit with the rust
    // QParams::fake_quant (both use round-half-to-even)
    for (i, (&a, &b)) in out[0].data.iter().zip(&x.data).enumerate() {
        let want = p.fake_quant(b);
        assert!(
            (a - want).abs() < 1e-6,
            "elem {i}: kernel {a} vs rust {want} (x={b})"
        );
    }
}

#[test]
fn kernel_int8_gemm_artifact_matches_vta_arithmetic() {
    let Some(dir) = artifacts() else { return };
    let path = dir.join("kernel_int8_gemm.hlo.txt");
    if !path.exists() {
        eprintln!("SKIP: {} missing", path.display());
        return;
    }
    let Some(rt) = runtime() else { return };
    let exe = rt.load(&path).unwrap();

    let (m, k, n) = (64, 96, 48);
    let mut rng = Pcg32::seeded(6);
    let a: Vec<i32> = (0..m * k).map(|_| rng.below(256) as i32 - 128).collect();
    let b: Vec<i32> = (0..k * n).map(|_| rng.below(256) as i32 - 128).collect();
    let bias: Vec<i32> = (0..n).map(|_| rng.below(2048) as i32 - 1024).collect();
    let (mul, shift) = (3i32, 9i32);

    let lits = [
        i32_to_literal(&a, &[m, k]).unwrap(),
        i32_to_literal(&b, &[k, n]).unwrap(),
        i32_to_literal(&bias, &[n]).unwrap(),
        i32_to_literal(&[mul, shift], &[2]).unwrap(),
    ];
    let refs: Vec<&xla::Literal> = lits.iter().collect();
    let out = exe.run_literals_i32(&refs).unwrap();
    assert_eq!(out[0].len(), m * n);

    // rust VTA-equivalent arithmetic (gemm_i32 + rshift_round)
    let mut acc = vec![0i32; m * n];
    quantune::interp::gemm::gemm_i32(m, k, n, &a, &b, &mut acc);
    for i in 0..m {
        for j in 0..n {
            let v = (acc[i * n + j] + bias[j]) as i64 * mul as i64;
            let want =
                quantune::vta::rshift_round(v, shift).clamp(-128, 127) as i32;
            assert_eq!(
                out[0][i * n + j],
                want,
                "({i},{j}): pallas {} vs vta {want}",
                out[0][i * n + j]
            );
        }
    }
}

#[test]
fn fp32_artifact_matches_interpreter() {
    let Some(dir) = artifacts() else { return };
    let name = "sqn";
    if !dir.join(format!("{name}_meta.json")).exists() {
        eprintln!("SKIP: {name} artifacts missing");
        return;
    }
    let model = ZooModel::load(&dir, name).unwrap();
    let Some(rt) = runtime() else { return };
    let exe = rt.load(&dir.join(format!("{name}_fp32_b1.hlo.txt"))).unwrap();

    let mut rng = Pcg32::seeded(7);
    let x = Tensor {
        shape: vec![1, 32, 32, 3],
        data: (0..32 * 32 * 3).map(|_| rng.range_f32(-1.0, 1.0)).collect(),
    };
    let mut inputs: Vec<&Tensor> = vec![&x];
    let flat = model.weights.flat();
    inputs.extend(flat.iter().copied());
    let hlo_logits = &exe.run_f32(&inputs).unwrap()[0];

    let interp = Interpreter::new(&model.graph, model.weights_map());
    let rust_logits = interp.forward(&x).unwrap();

    assert_eq!(hlo_logits.shape, rust_logits.shape);
    for (i, (&a, &b)) in hlo_logits.data.iter().zip(&rust_logits.data).enumerate() {
        assert!(
            (a - b).abs() < 1e-2 + 1e-3 * b.abs().max(1.0),
            "logit {i}: hlo {a} vs interp {b}"
        );
    }
    assert_eq!(argmax_batch(hlo_logits), argmax_batch(&rust_logits));
}

#[test]
fn executable_cache_compiles_once() {
    let Some(dir) = artifacts() else { return };
    let path = dir.join("kernel_fake_quant.hlo.txt");
    if !path.exists() {
        return;
    }
    let Some(rt) = runtime() else { return };
    let a = rt.load(&path).unwrap();
    let b = rt.load(&path).unwrap();
    assert!(std::rc::Rc::ptr_eq(&a, &b));
    assert_eq!(rt.cached(), 1);
}
