//! Determinism and equivalence guarantees of the multi-fidelity racing
//! search (successive halving, PR 9):
//!
//! - with `fidelity_min = 1` (a single full-fidelity rung) racing
//!   degenerates bit-for-bit to the plain `run_search` loop for every
//!   scalar proposer, measured live through the interpreter;
//! - a racing run -- promotion sets included -- is byte-identical at
//!   1/2/4/8 evaluator threads;
//! - the `sh` CLI name works end to end through `Quantune::search_racing`
//!   but is refused by `make_algorithm` (it is a scheduler, not a
//!   proposer), and `nsga2` refuses to race at all;
//! - the `racing_synthetic` experiment recovers the exhaustive best at
//!   under 40% of the exhaustive evaluation cost (the ISSUE acceptance
//!   bar), and the live-interpreter stage stays under 1.0;
//! - fidelity-tagged records round-trip both trial-store backends, and
//!   legacy records (no `fidelity` field) read back as full fidelity.
//!
//! Everything runs on synthetic models/datasets (no artifacts needed).

use std::fs;
use std::path::PathBuf;

use quantune::coordinator::{
    self, records_equal, InterpEvaluator, Quantune, Record, SharedEvaluator, Store,
    TrialStore, GENERAL_SPACE_TAG,
};
use quantune::data::{synthetic_dataset, Dataset};
use quantune::quant::general_space;
use quantune::search::{run_racing, run_search, RacingOptions, SearchTrace, TransferRecord};
use quantune::zoo::{synthetic_model, ZooModel};

const THREAD_COUNTS: [usize; 4] = [1, 2, 4, 8];

/// Scalar proposers that can race (`nsga2` is excluded by design: its
/// non-dominated ranking needs full component vectors).
const RACEABLE: [&str; 5] = ["random", "grid", "genetic", "xgb", "xgb_t"];

fn setup() -> (ZooModel, Dataset, Dataset) {
    let model = synthetic_model(8, 4, 4, 3).unwrap();
    let calib = synthetic_dataset(32, 8, 8, 4, 4, 5);
    let eval = synthetic_dataset(96, 8, 8, 4, 4, 6);
    (model, calib, eval)
}

fn transfer_for(model: &ZooModel) -> Vec<TransferRecord> {
    let space = general_space();
    (0..96)
        .map(|i| TransferRecord {
            features: coordinator::features_for(model, space.as_ref(), i).unwrap(),
            accuracy: 0.4 + (i % 7) as f32 * 0.05,
            fidelity: 1.0,
        })
        .collect()
}

/// Everything a trial carries, bit-exact (config, score, fidelity, cost).
fn trace_key(t: &SearchTrace) -> Vec<(usize, u64, u64, u64)> {
    t.trials
        .iter()
        .map(|tr| (tr.config, tr.score.to_bits(), tr.fidelity.to_bits(), tr.cost.to_bits()))
        .collect()
}

fn tmpdir(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(name);
    let _ = fs::remove_dir_all(&dir);
    dir
}

/// `fidelity_min = 1` => one full-fidelity rung, generation size 1: the
/// scheduler must reproduce the plain search loop trial-for-trial
/// (same proposals, bit-identical scores, same cost) for every scalar
/// proposer, measuring live through the interpreter.
#[test]
fn full_fidelity_racing_degenerates_to_the_plain_search() {
    let (model, calib, eval) = setup();
    let space = general_space();
    let transfer = transfer_for(&model);
    let seed = 20220205u64;
    let budget = 6;
    let opts = RacingOptions { eta: 4, fidelity_min: 1.0 };
    for algo in RACEABLE {
        let ev = InterpEvaluator::new(&model, &calib, &eval, seed);
        let mut plain_algo =
            coordinator::make_algorithm(algo, &model, &space, transfer.clone(), seed).unwrap();
        let plain =
            run_search(plain_algo.as_mut(), budget, |cfg| ev.measure_shared(cfg)).unwrap();

        let ev = InterpEvaluator::new(&model, &calib, &eval, seed);
        let mut raced_algo =
            coordinator::make_algorithm(algo, &model, &space, transfer.clone(), seed).unwrap();
        let raced = run_racing(raced_algo.as_mut(), budget, opts, |cfg, fid| {
            ev.measure_fidelity_shared(cfg, fid)
        })
        .unwrap();

        assert_eq!(raced.algo, format!("sh({})", plain.algo), "{algo}");
        assert_eq!(trace_key(&plain), trace_key(&raced), "{algo}: traces diverged");
        assert_eq!(plain.best_config, raced.best_config, "{algo}");
        assert_eq!(plain.best_score.to_bits(), raced.best_score.to_bits(), "{algo}");
        assert!(raced.trials.iter().all(|t| t.fidelity == 1.0), "{algo}");
    }
}

/// The full racing ladder (1/16 -> 1/4 -> 1) must produce a
/// byte-identical trace -- proposals, low-fidelity scores, promotion
/// sets, costs -- at every evaluator thread count.
#[test]
fn racing_traces_identical_across_thread_counts() {
    let (model, calib, eval) = setup();
    let space = general_space();
    let transfer = transfer_for(&model);
    let seed = 20220205u64;
    let budget = 16;
    let opts = RacingOptions { eta: 4, fidelity_min: 1.0 / 16.0 };
    for algo in RACEABLE {
        let run_at = |threads: usize| -> SearchTrace {
            let ev =
                InterpEvaluator::new(&model, &calib, &eval, seed).with_threads(threads);
            let mut search =
                coordinator::make_algorithm(algo, &model, &space, transfer.clone(), seed)
                    .unwrap();
            run_racing(search.as_mut(), budget, opts, |cfg, fid| {
                ev.measure_fidelity_shared(cfg, fid)
            })
            .unwrap()
        };
        let base = run_at(THREAD_COUNTS[0]);
        // cursor proposers fill a whole generation: 16 base-rung
        // trials, 4 promotions, 1 full (population proposers may race
        // a shorter cohort when the dedup guard trips)
        if matches!(algo, "random" | "grid") {
            assert_eq!(base.trials.len(), 21, "{algo}");
        }
        assert!(base.trials.iter().filter(|t| t.fidelity >= 1.0).count() >= 1, "{algo}");
        for &threads in &THREAD_COUNTS[1..] {
            let t = run_at(threads);
            assert_eq!(
                trace_key(&base),
                trace_key(&t),
                "{algo}: racing trace diverged between 1 and {threads} threads"
            );
            assert_eq!(base.best_config, t.best_config, "{algo}");
            assert_eq!(base.best_score.to_bits(), t.best_score.to_bits(), "{algo}");
        }
    }
}

/// The `sh` name works end to end through the coordinator (random
/// proposals under the scheduler), is refused as a plain proposer, and
/// `nsga2` is refused as a racing proposer.
#[test]
fn sh_races_through_the_coordinator_and_nsga2_refuses() {
    let (model, calib, eval) = setup();
    let q = Quantune {
        artifacts: PathBuf::from("."),
        calib_pool: calib.clone(),
        eval: eval.clone(),
        db: Store::in_memory(),
        seed: 1,
        device: coordinator::DEVICES[1],
        seed_from_db: false,
    };
    let space = general_space();
    let seed = 7u64;
    let opts = RacingOptions { eta: 4, fidelity_min: 0.25 };
    let mut ev = InterpEvaluator::new(&model, &calib, &eval, seed);
    let trace = q.search_racing(&model, &space, "sh", &mut ev, 8, seed, opts).unwrap();
    assert_eq!(trace.algo, "sh(random)");
    assert!(trace.trials.iter().any(|t| t.fidelity >= 1.0));
    assert!(trace.total_cost() < trace.trials.len() as f64, "partial rungs must be cheaper");

    let err = coordinator::make_algorithm("sh", &model, &space, Vec::new(), seed)
        .err()
        .expect("sh must not construct as a plain proposer");
    assert!(err.to_string().contains("racing scheduler"), "{err}");

    let mut ev = InterpEvaluator::new(&model, &calib, &eval, seed);
    let err = q
        .search_racing(&model, &space, "nsga2", &mut ev, 8, seed, opts)
        .err()
        .expect("nsga2 must refuse to race");
    assert!(err.to_string().contains("nsga2"), "{err}");
}

/// The ISSUE acceptance bar: `racing_synthetic` recovers the exhaustive
/// best score at under 40% of the exhaustive evaluation cost on the
/// provable surface stage, and the live-interpreter stage races the VTA
/// space for strictly less than an exhaustive sweep.
#[test]
fn racing_synthetic_recovers_the_best_under_forty_percent_cost() {
    let out = tmpdir("quantune_racing_results");
    std::env::set_var("QUANTUNE_RESULTS", &out);
    let rows = quantune::experiments::racing_synthetic().unwrap();
    std::env::remove_var("QUANTUNE_RESULTS");
    assert_eq!(rows.len(), 2);

    let surface = &rows[0];
    assert_eq!(surface.stage, "surface");
    assert!(surface.recovered, "racing missed the analytic optimum");
    assert_eq!(surface.racing_score, surface.exhaustive_score);
    assert!(
        surface.cost_fraction < 0.4,
        "surface stage cost {:.3} of exhaustive, want < 0.4",
        surface.cost_fraction
    );

    let interp = &rows[1];
    assert_eq!(interp.stage, "interp");
    assert!(interp.full_trials >= 1, "no full-fidelity winner measured");
    assert!(
        interp.cost_fraction < 1.0,
        "interp stage cost {:.3} of exhaustive, want < 1.0",
        interp.cost_fraction
    );

    let csv = fs::read_to_string(out.join("racing_synthetic.csv")).unwrap();
    assert!(csv.starts_with("stage,algo,exhaustive_best,"), "{csv}");
    assert_eq!(csv.lines().count(), 1 + rows.len());
    let _ = fs::remove_dir_all(&out);
}

/// Fidelity-tagged records survive both store backends bit-for-bit, and
/// a legacy record (no `fidelity` field in the JSON) reads back as full
/// fidelity on both.
#[test]
fn fidelity_records_round_trip_both_store_backends() {
    let recs = vec![
        Record {
            fidelity: Some(0.0625),
            ..Record::new("mn".into(), GENERAL_SPACE_TAG.into(), 3, 0.71, 0.5)
        },
        Record {
            fidelity: Some(1.0),
            ..Record::new("mn".into(), GENERAL_SPACE_TAG.into(), 4, 0.74, 0.5)
        },
        Record::new("mn".into(), GENERAL_SPACE_TAG.into(), 5, 0.69, 0.5), // legacy: None
    ];
    assert!(!recs[0].is_full_fidelity());
    assert!(recs[1].is_full_fidelity());
    assert!(recs[2].is_full_fidelity());

    // JSON backend: write database.json, reopen through the auto-detect
    let json_dir = tmpdir("quantune_racing_store_json");
    fs::create_dir_all(&json_dir).unwrap();
    let mut store = Store::open_json(&json_dir.join("database.json")).unwrap();
    for r in &recs {
        store.add(r.clone()).unwrap();
    }
    store.save().unwrap();
    let reopened = Store::open(&json_dir).unwrap();
    assert_eq!(reopened.backend(), "json");
    assert_eq!(reopened.records().len(), recs.len());
    for (a, b) in recs.iter().zip(reopened.records()) {
        assert!(records_equal(a, b), "json backend dropped fidelity: {a:?} vs {b:?}");
    }

    // log backend: segmented frames are Record JSON, same guarantee
    let log_dir = tmpdir("quantune_racing_store_log");
    let mut store = Store::open_log(&log_dir.join("trials")).unwrap();
    for r in &recs {
        store.add(r.clone()).unwrap();
    }
    store.save().unwrap();
    let reopened = Store::open(&log_dir).unwrap();
    assert_eq!(reopened.backend(), "log");
    assert_eq!(reopened.records().len(), recs.len());
    for (a, b) in recs.iter().zip(reopened.records()) {
        assert!(records_equal(a, b), "log backend dropped fidelity: {a:?} vs {b:?}");
    }

    // a hand-written legacy file (no fidelity field anywhere) parses to
    // full-fidelity records on the modern reader
    let legacy_dir = tmpdir("quantune_racing_store_legacy");
    fs::create_dir_all(&legacy_dir).unwrap();
    fs::write(
        legacy_dir.join("database.json"),
        r#"{"records": [{"model": "sqn", "space": "general", "config": 1,
            "accuracy": 0.5, "measure_secs": 0.1}]}"#,
    )
    .unwrap();
    let legacy = Store::open(&legacy_dir).unwrap();
    assert_eq!(legacy.records().len(), 1);
    assert_eq!(legacy.records()[0].fidelity, None);
    assert!(legacy.records()[0].is_full_fidelity());

    for d in [json_dir, log_dir, legacy_dir] {
        let _ = fs::remove_dir_all(d);
    }
}
