//! End-to-end guarantees of the integer kernel engine (ROADMAP item 1).
//!
//! The unit tests in `interp/kernels.rs` pin the microkernels against
//! naive integer references; this suite covers the full interpreter
//! path: for every scheme x granularity x {int4, int8, mixed} the
//! integer route ([`Interpreter::with_int_weights`]) must agree with
//! the legacy f32 fake-quant route to float-accumulation noise and
//! produce identical Top-1 predictions, with the int-weight map coming
//! out of the real quantizer ([`prepare_cached`]). Runs entirely on
//! synthetic models/datasets -- no artifacts needed.

use std::collections::HashMap;
use std::sync::Arc;

use quantune::calib::{calibrate, CalibBackend};
use quantune::coordinator::{prepare_cached, WeightCache};
use quantune::data::synthetic_dataset;
use quantune::interp::{argmax_batch, Interpreter};
use quantune::ir::Tensor;
use quantune::quant::{
    BitWidth, CalibCount, Clipping, Granularity, QuantConfig, QuantPlan, Scheme,
    ALL_SCHEMES,
};
use quantune::zoo::synthetic_model;

/// Max |a - b| over two logit tensors.
fn max_abs_diff(a: &Tensor, b: &Tensor) -> f32 {
    a.data
        .iter()
        .zip(&b.data)
        .map(|(x, y)| (x - y).abs())
        .fold(0.0f32, f32::max)
}

/// Run one plan through both interpreter routes and return
/// (f32-route logits, integer-route logits, #layers on the int path).
fn both_routes(
    scheme: Scheme,
    gran: Granularity,
    layer_widths: Option<Vec<BitWidth>>,
) -> (Tensor, Tensor, usize) {
    let model = synthetic_model(8, 4, 4, 3).unwrap();
    let calib = synthetic_dataset(16, 8, 8, 4, 4, 5);
    let eval = synthetic_dataset(64, 8, 8, 4, 4, 6);
    let cache = calibrate(&model, &calib, CalibCount::C1, &CalibBackend::Interp, 1)
        .unwrap();
    let base = QuantConfig {
        calib: CalibCount::C1,
        scheme,
        clip: Clipping::Max,
        gran,
        mixed: false,
    };
    let plan = QuantPlan { base, layer_widths };
    let setup =
        prepare_cached(&model, &cache, &plan, &WeightCache::new()).unwrap();
    let weights: HashMap<String, Arc<Tensor>> = model
        .weights
        .order
        .iter()
        .cloned()
        .zip(setup.weights.iter().cloned())
        .collect();
    let x = eval.batch(&(0..eval.n).collect::<Vec<_>>());

    let f32_route = Interpreter::new(&model.graph, &weights);
    let a = f32_route.forward_fq(&x, &setup.aq).unwrap();
    let int_route =
        Interpreter::new(&model.graph, &weights).with_int_weights(&setup.int_weights);
    let b = int_route.forward_fq(&x, &setup.aq).unwrap();
    (a, b, setup.int_weights.len())
}

#[test]
fn int8_route_agrees_with_f32_route_on_every_scheme() {
    for scheme in ALL_SCHEMES {
        for gran in [Granularity::Tensor, Granularity::Channel] {
            let (a, b, n_int) = both_routes(scheme, gran, None);
            // all three weighted layers (c1, c2, d) carry int8 weights
            assert_eq!(n_int, 3, "{scheme:?}/{gran:?}");
            // same math, different accumulation (exact integer vs f32):
            // agree to float noise, scaled to these logit magnitudes
            let diff = max_abs_diff(&a, &b);
            assert!(diff < 2e-3, "{scheme:?}/{gran:?}: logits diverged by {diff}");
            assert_eq!(
                argmax_batch(&a),
                argmax_batch(&b),
                "{scheme:?}/{gran:?}: predictions diverged"
            );
        }
    }
}

#[test]
fn int4_and_mixed_widths_dispatch_correctly() {
    // c1 int4 (packed nibbles), c2 fp32 (must fall back), d int8
    let widths = vec![BitWidth::Int4, BitWidth::Fp32, BitWidth::Int8];
    let (a, b, n_int) =
        both_routes(Scheme::Asymmetric, Granularity::Channel, Some(widths));
    // only the int4 + int8 layers get integer weights; the fp32 layer
    // (and everything downstream of its off-grid output) falls back
    assert_eq!(n_int, 2);
    let diff = max_abs_diff(&a, &b);
    assert!(diff < 2e-3, "mixed-width logits diverged by {diff}");
    assert_eq!(argmax_batch(&a), argmax_batch(&b));

    // all-int4: every layer on the packed-nibble kernel
    let widths = vec![BitWidth::Int4; 3];
    let (a, b, n_int) =
        both_routes(Scheme::Symmetric, Granularity::Tensor, Some(widths));
    assert_eq!(n_int, 3);
    let diff = max_abs_diff(&a, &b);
    assert!(diff < 2e-3, "int4 logits diverged by {diff}");
    assert_eq!(argmax_batch(&a), argmax_batch(&b));
}

#[test]
fn int16_stays_on_f32_route() {
    // int16 exceeds the i8 operand kernels: no integer weights built,
    // both routes are literally the same code path
    let widths = vec![BitWidth::Int16; 3];
    let (a, b, n_int) =
        both_routes(Scheme::Asymmetric, Granularity::Tensor, Some(widths));
    assert_eq!(n_int, 0);
    assert_eq!(a.data, b.data, "identical path must produce identical bits");
}

#[test]
fn fp32_and_acts_modes_ignore_int_weights() {
    // the integer path is a fake-quant-only dispatch: plain fp32
    // forwards (and calibration captures) must be bit-identical with
    // and without an attached int-weight map
    let model = synthetic_model(8, 4, 4, 3).unwrap();
    let calib = synthetic_dataset(16, 8, 8, 4, 4, 5);
    let cache = calibrate(&model, &calib, CalibCount::C1, &CalibBackend::Interp, 1)
        .unwrap();
    let base = QuantConfig {
        calib: CalibCount::C1,
        scheme: Scheme::Asymmetric,
        clip: Clipping::Max,
        gran: Granularity::Tensor,
        mixed: false,
    };
    let setup =
        prepare_cached(&model, &cache, &base.into(), &WeightCache::new()).unwrap();
    let x = calib.batch(&[0, 1, 2]);
    let plain = Interpreter::new(&model.graph, model.weights_map());
    let with_int = Interpreter::new(&model.graph, model.weights_map())
        .with_int_weights(&setup.int_weights);
    let a = plain.forward(&x).unwrap();
    let b = with_int.forward(&x).unwrap();
    assert_eq!(a.data, b.data);
    let (_, acts_a) = plain.forward_acts(&x).unwrap();
    let (_, acts_b) = with_int.forward_acts(&x).unwrap();
    for (ta, tb) in acts_a.iter().zip(&acts_b) {
        assert_eq!(ta.data, tb.data);
    }
}

#[test]
fn grid_recovery_is_exact_for_all_schemes() {
    // the integer path's keystone: re-quantizing a fake-quant value
    // recovers its grid index exactly, for every scheme's params over a
    // representative range
    for scheme in ALL_SCHEMES {
        let p = scheme.params_from_range(-3.7, 5.3);
        let (lo, hi) = (p.qmin as i32, p.qmax as i32);
        for q in lo..=hi {
            let v = (q - p.zero_point) as f32 * p.scale;
            let rq = p.quantize(v);
            assert_eq!(rq, q, "{scheme:?}: grid point {q} recovered as {rq} (v = {v})");
        }
    }
}
