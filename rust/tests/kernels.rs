//! End-to-end guarantees of the integer kernel engine (ROADMAP item 1).
//!
//! The unit tests in `interp/kernels.rs` pin the microkernels against
//! naive integer references; this suite covers the full interpreter
//! path: for every scheme x granularity x {int4, int8, mixed} the
//! integer route ([`Interpreter::with_int_weights`]) must agree with
//! the legacy f32 fake-quant route to float-accumulation noise and
//! produce identical Top-1 predictions, with the int-weight map coming
//! out of the real quantizer ([`prepare_cached`]). Also covered here:
//! integer-resident chains through pool/concat/shuffle-free graphs
//! (conv -> max-pool -> conv -> concat -> gap -> dense), the avg-pool
//! integer route, per-evaluation dispatch accounting, and Top-1
//! invariance across worker thread counts. Runs entirely on synthetic
//! models/datasets -- no artifacts needed.

use std::collections::HashMap;
use std::sync::Arc;

use quantune::calib::{calibrate, CalibBackend};
use quantune::coordinator::{prepare_cached, InterpEvaluator, SharedEvaluator, WeightCache};
use quantune::data::{synthetic_dataset, Weights};
use quantune::interp::{argmax_batch, Interpreter};
use quantune::ir::{Graph, Op, Tensor};
use quantune::metrics::DispatchCounters;
use quantune::quant::{
    BitWidth, CalibCount, Clipping, Granularity, QuantConfig, QuantPlan, Scheme,
    ALL_SCHEMES,
};
use quantune::util::{Json, Pcg32};
use quantune::zoo::{synthetic_model, ZooModel};

/// Max |a - b| over two logit tensors.
fn max_abs_diff(a: &Tensor, b: &Tensor) -> f32 {
    a.data
        .iter()
        .zip(&b.data)
        .map(|(x, y)| (x - y).abs())
        .fold(0.0f32, f32::max)
}

/// Run one plan through both interpreter routes and return
/// (f32-route logits, integer-route logits, #layers on the int path).
fn both_routes(
    scheme: Scheme,
    gran: Granularity,
    layer_widths: Option<Vec<BitWidth>>,
) -> (Tensor, Tensor, usize) {
    let model = synthetic_model(8, 4, 4, 3).unwrap();
    let calib = synthetic_dataset(16, 8, 8, 4, 4, 5);
    let eval = synthetic_dataset(64, 8, 8, 4, 4, 6);
    let cache = calibrate(&model, &calib, CalibCount::C1, &CalibBackend::Interp, 1)
        .unwrap();
    let base = QuantConfig {
        calib: CalibCount::C1,
        scheme,
        clip: Clipping::Max,
        gran,
        mixed: false,
        bias_correct: false,
    };
    let plan = QuantPlan { base, layer_widths };
    let setup =
        prepare_cached(&model, &cache, &plan, &WeightCache::new()).unwrap();
    let weights: HashMap<String, Arc<Tensor>> = model
        .weights
        .order
        .iter()
        .cloned()
        .zip(setup.weights.iter().cloned())
        .collect();
    let x = eval.batch(&(0..eval.n).collect::<Vec<_>>());

    let f32_route = Interpreter::new(&model.graph, &weights);
    let a = f32_route.forward_fq(&x, &setup.aq).unwrap();
    let int_route =
        Interpreter::new(&model.graph, &weights).with_int_weights(&setup.int_weights);
    let b = int_route.forward_fq(&x, &setup.aq).unwrap();
    (a, b, setup.int_weights.len())
}

#[test]
fn int8_route_agrees_with_f32_route_on_every_scheme() {
    for scheme in ALL_SCHEMES {
        for gran in [Granularity::Tensor, Granularity::Channel] {
            let (a, b, n_int) = both_routes(scheme, gran, None);
            // all three weighted layers (c1, c2, d) carry int8 weights
            assert_eq!(n_int, 3, "{scheme:?}/{gran:?}");
            // same math, different accumulation (exact integer vs f32):
            // agree to float noise, scaled to these logit magnitudes
            let diff = max_abs_diff(&a, &b);
            assert!(diff < 2e-3, "{scheme:?}/{gran:?}: logits diverged by {diff}");
            assert_eq!(
                argmax_batch(&a),
                argmax_batch(&b),
                "{scheme:?}/{gran:?}: predictions diverged"
            );
        }
    }
}

#[test]
fn int4_and_mixed_widths_dispatch_correctly() {
    // c1 int4 (packed nibbles), c2 fp32 (must fall back), d int8
    let widths = vec![BitWidth::Int4, BitWidth::Fp32, BitWidth::Int8];
    let (a, b, n_int) =
        both_routes(Scheme::Asymmetric, Granularity::Channel, Some(widths));
    // only the int4 + int8 layers get integer weights; the fp32 layer
    // (and everything downstream of its off-grid output) falls back
    assert_eq!(n_int, 2);
    let diff = max_abs_diff(&a, &b);
    assert!(diff < 2e-3, "mixed-width logits diverged by {diff}");
    assert_eq!(argmax_batch(&a), argmax_batch(&b));

    // all-int4: every layer on the packed-nibble kernel
    let widths = vec![BitWidth::Int4; 3];
    let (a, b, n_int) =
        both_routes(Scheme::Symmetric, Granularity::Tensor, Some(widths));
    assert_eq!(n_int, 3);
    let diff = max_abs_diff(&a, &b);
    assert!(diff < 2e-3, "int4 logits diverged by {diff}");
    assert_eq!(argmax_batch(&a), argmax_batch(&b));
}

#[test]
fn int16_stays_on_f32_route() {
    // int16 exceeds the i8 operand kernels: no integer weights built,
    // both routes are literally the same code path
    let widths = vec![BitWidth::Int16; 3];
    let (a, b, n_int) =
        both_routes(Scheme::Asymmetric, Granularity::Tensor, Some(widths));
    assert_eq!(n_int, 0);
    assert_eq!(a.data, b.data, "identical path must produce identical bits");
}

#[test]
fn fp32_and_acts_modes_ignore_int_weights() {
    // the integer path is a fake-quant-only dispatch: plain fp32
    // forwards (and calibration captures) must be bit-identical with
    // and without an attached int-weight map
    let model = synthetic_model(8, 4, 4, 3).unwrap();
    let calib = synthetic_dataset(16, 8, 8, 4, 4, 5);
    let cache = calibrate(&model, &calib, CalibCount::C1, &CalibBackend::Interp, 1)
        .unwrap();
    let base = QuantConfig {
        calib: CalibCount::C1,
        scheme: Scheme::Asymmetric,
        clip: Clipping::Max,
        gran: Granularity::Tensor,
        mixed: false,
        bias_correct: false,
    };
    let setup =
        prepare_cached(&model, &cache, &base.into(), &WeightCache::new()).unwrap();
    let x = calib.batch(&[0, 1, 2]);
    let plain = Interpreter::new(&model.graph, model.weights_map());
    let with_int = Interpreter::new(&model.graph, model.weights_map())
        .with_int_weights(&setup.int_weights);
    let a = plain.forward(&x).unwrap();
    let b = with_int.forward(&x).unwrap();
    assert_eq!(a.data, b.data);
    let (_, acts_a) = plain.forward_acts(&x).unwrap();
    let (_, acts_b) = with_int.forward_acts(&x).unwrap();
    for (ta, tb) in acts_a.iter().zip(&acts_b) {
        assert_eq!(ta.data, tb.data);
    }
}

/// Build a [`ZooModel`] from inline meta JSON with seeded He-init
/// weights -- the same construction as [`synthetic_model`], for custom
/// topologies (pools, branches, concat).
fn model_from_meta(meta_text: &str, seed: u64) -> ZooModel {
    let graph = Graph::from_meta(&Json::parse(meta_text).unwrap()).unwrap();
    let mut rng = Pcg32::new(seed, 41);
    let mut tensors = HashMap::new();
    let mut order = Vec::new();
    for node in &graph.nodes {
        let (w_shape, b_len): (Vec<usize>, usize) = match &node.op {
            Op::Conv { k, in_ch, out_ch, groups, .. } => {
                (vec![*k, *k, in_ch / groups, *out_ch], *out_ch)
            }
            Op::Dense { in_dim, out_dim } => (vec![*in_dim, *out_dim], *out_dim),
            _ => continue,
        };
        let fan_in: usize = w_shape[..w_shape.len() - 1].iter().product();
        let scale = (2.0 / fan_in.max(1) as f32).sqrt();
        let wn: usize = w_shape.iter().product();
        let w = Tensor {
            shape: w_shape,
            data: (0..wn).map(|_| rng.normal() * scale).collect(),
        };
        let b = Tensor {
            shape: vec![b_len],
            data: (0..b_len).map(|_| rng.normal() * 0.05).collect(),
        };
        for (suffix, t) in [("w", w), ("b", b)] {
            let name = format!("{}_{suffix}", node.name);
            order.push(name.clone());
            tensors.insert(name, t);
        }
    }
    ZooModel {
        name: "chain".to_string(),
        graph,
        weights: Weights { tensors, order },
        fp32_top1: 0.5,
        batch: 16,
    }
}

/// conv -> max-pool -> (conv, conv) -> concat -> gap -> dense: every
/// integer-resident op of the PR 7 pipeline in one graph. Weighted
/// layers in graph order: c1, c2a, c2b, d.
const CHAIN_META: &str = r#"{"name": "chain", "input_shape": [8, 8, 4], "num_classes": 4,
  "nodes": [
    {"name": "c1", "op": "conv", "inputs": ["input"], "k": 3, "stride": 1,
     "pad": 1, "in_ch": 4, "out_ch": 8, "groups": 1, "act": "relu"},
    {"name": "p1", "op": "pool", "inputs": ["c1"], "kind": "max", "k": 2,
     "stride": 2, "pad": 0},
    {"name": "c2a", "op": "conv", "inputs": ["p1"], "k": 3, "stride": 1,
     "pad": 1, "in_ch": 8, "out_ch": 8, "groups": 1, "act": "relu"},
    {"name": "c2b", "op": "conv", "inputs": ["p1"], "k": 1, "stride": 1,
     "pad": 0, "in_ch": 8, "out_ch": 8, "groups": 1, "act": "none"},
    {"name": "cc", "op": "concat", "inputs": ["c2a", "c2b"]},
    {"name": "g", "op": "gap", "inputs": ["cc"]},
    {"name": "d", "op": "dense", "inputs": ["g"], "in_dim": 16, "out_dim": 4}]}"#;

/// Same skeleton with an average pool: the int route crosses a
/// documented f32 boundary there. Weighted layers: c1, c2, d.
const AVG_META: &str = r#"{"name": "chain", "input_shape": [8, 8, 4], "num_classes": 4,
  "nodes": [
    {"name": "c1", "op": "conv", "inputs": ["input"], "k": 3, "stride": 1,
     "pad": 1, "in_ch": 4, "out_ch": 8, "groups": 1, "act": "relu"},
    {"name": "p1", "op": "pool", "inputs": ["c1"], "kind": "avg", "k": 2,
     "stride": 2, "pad": 0},
    {"name": "c2", "op": "conv", "inputs": ["p1"], "k": 3, "stride": 1,
     "pad": 1, "in_ch": 8, "out_ch": 8, "groups": 1, "act": "relu"},
    {"name": "g", "op": "gap", "inputs": ["c2"]},
    {"name": "d", "op": "dense", "inputs": ["g"], "in_dim": 8, "out_dim": 4}]}"#;

/// Run one plan through both routes on a custom-topology model and
/// return (f32 logits, int logits, #int layers, (int, fallback)
/// dispatch tallies of the integer route).
fn chain_routes(
    meta: &str,
    scheme: Scheme,
    gran: Granularity,
    layer_widths: Option<Vec<BitWidth>>,
) -> (Tensor, Tensor, usize, (u64, u64)) {
    let model = model_from_meta(meta, 9);
    let calib = synthetic_dataset(16, 8, 8, 4, 4, 5);
    let eval = synthetic_dataset(32, 8, 8, 4, 4, 6);
    let cache = calibrate(&model, &calib, CalibCount::C1, &CalibBackend::Interp, 1)
        .unwrap();
    let base = QuantConfig {
        calib: CalibCount::C1,
        scheme,
        clip: Clipping::Max,
        gran,
        mixed: false,
        bias_correct: false,
    };
    let plan = QuantPlan { base, layer_widths };
    let setup =
        prepare_cached(&model, &cache, &plan, &WeightCache::new()).unwrap();
    let weights: HashMap<String, Arc<Tensor>> = model
        .weights
        .order
        .iter()
        .cloned()
        .zip(setup.weights.iter().cloned())
        .collect();
    let x = eval.batch(&(0..eval.n).collect::<Vec<_>>());

    let f32_route = Interpreter::new(&model.graph, &weights);
    let a = f32_route.forward_fq(&x, &setup.aq).unwrap();
    let counters = DispatchCounters::new();
    let int_route = Interpreter::new(&model.graph, &weights)
        .with_int_weights(&setup.int_weights)
        .with_dispatch_counters(&counters);
    let b = int_route.forward_fq(&x, &setup.aq).unwrap();
    let s = counters.snapshot();
    (a, b, setup.int_weights.len(), (s.int_layers, s.fallback_layers))
}

#[test]
fn integer_chain_agrees_on_every_scheme() {
    // conv -> max-pool -> conv -> concat -> gap -> dense stays
    // integer-resident end to end: max-pool passes i8 through, concat
    // and gap dequantize in the oracle's accumulation order, and every
    // weighted layer dispatches to the packed kernels
    for scheme in ALL_SCHEMES {
        for gran in [Granularity::Tensor, Granularity::Channel] {
            let (a, b, n_int, (int_l, fb_l)) =
                chain_routes(CHAIN_META, scheme, gran, None);
            assert_eq!(n_int, 4, "{scheme:?}/{gran:?}");
            assert_eq!((int_l, fb_l), (4, 0), "{scheme:?}/{gran:?}: dispatch");
            let diff = max_abs_diff(&a, &b);
            assert!(diff < 2e-3, "{scheme:?}/{gran:?}: logits diverged by {diff}");
            assert_eq!(
                argmax_batch(&a),
                argmax_batch(&b),
                "{scheme:?}/{gran:?}: predictions diverged"
            );
        }
    }
}

#[test]
fn integer_chain_handles_mixed_and_int4_widths() {
    // c2b stays fp32: its dispatch falls back, its output leaves the
    // grid, and the concat re-quantizes the merged tensor at its own
    // (active) quant point so the dense head returns to the int path
    let widths =
        vec![BitWidth::Int8, BitWidth::Int4, BitWidth::Fp32, BitWidth::Int8];
    let (a, b, n_int, (int_l, fb_l)) =
        chain_routes(CHAIN_META, Scheme::Asymmetric, Granularity::Channel, Some(widths));
    assert_eq!(n_int, 3);
    assert_eq!((int_l, fb_l), (3, 1), "c2b must be the only fallback");
    let diff = max_abs_diff(&a, &b);
    assert!(diff < 2e-3, "mixed chain logits diverged by {diff}");
    assert_eq!(argmax_batch(&a), argmax_batch(&b));

    // all-int4: the whole chain on packed-nibble weights
    let widths = vec![BitWidth::Int4; 4];
    let (a, b, n_int, (int_l, fb_l)) =
        chain_routes(CHAIN_META, Scheme::Symmetric, Granularity::Tensor, Some(widths));
    assert_eq!(n_int, 4);
    assert_eq!((int_l, fb_l), (4, 0));
    let diff = max_abs_diff(&a, &b);
    assert!(diff < 2e-3, "int4 chain logits diverged by {diff}");
    assert_eq!(argmax_batch(&a), argmax_batch(&b));
}

#[test]
fn avg_pool_integer_route_stays_near_oracle() {
    // the i32-summed average pool is a documented f32 boundary: its
    // result is the same window mean with a different rounding order,
    // so the downstream conv re-enters via the f32 fallback and the
    // routes agree to (at worst) one grid step of requantization slack
    let (a, b, n_int, (int_l, fb_l)) =
        chain_routes(AVG_META, Scheme::Asymmetric, Granularity::Channel, None);
    assert_eq!(n_int, 3);
    // c1 and d run integer; c2 consumes the avg pool's f32 output
    assert_eq!((int_l, fb_l), (2, 1));
    assert!(b.data.iter().all(|v| v.is_finite()));
    let diff = max_abs_diff(&a, &b);
    assert!(diff < 0.25, "avg-pool chain logits diverged by {diff}");
    let (pa, pb) = (argmax_batch(&a), argmax_batch(&b));
    let flips = pa.iter().zip(&pb).filter(|(x, y)| x != y).count();
    assert!(flips <= 2, "avg-pool chain flipped {flips}/32 predictions");
}

#[test]
fn thread_count_is_invisible_to_measured_top1() {
    // the batch fan-out reduces hit counts in input order, and every
    // worker's scratch arena is private: Top-1 must be bit-identical at
    // any QUANTUNE_THREADS-style worker count
    let model = synthetic_model(8, 4, 4, 3).unwrap();
    let calib = synthetic_dataset(16, 8, 8, 4, 4, 5);
    let eval = synthetic_dataset(160, 8, 8, 4, 4, 6);
    for config in [0usize, 13] {
        let mut accs = Vec::new();
        for threads in [1usize, 2, 4, 8] {
            let ev = InterpEvaluator::new(&model, &calib, &eval, 1)
                .with_threads(threads);
            accs.push(ev.measure_shared(config).unwrap());
        }
        assert!(
            accs.windows(2).all(|w| w[0] == w[1]),
            "config {config}: Top-1 varies with thread count: {accs:?}"
        );
    }
}

#[test]
fn evaluator_dispatch_stats_track_integer_sweep() {
    let model = synthetic_model(8, 4, 4, 3).unwrap();
    let calib = synthetic_dataset(16, 8, 8, 4, 4, 5);
    let eval = synthetic_dataset(64, 8, 8, 4, 4, 6);
    let ev = InterpEvaluator::new(&model, &calib, &eval, 1).with_threads(2);
    ev.measure_shared(0).unwrap();
    let s = ev.dispatch_stats();
    // 64 eval images = one batch; all three weighted layers went integer
    assert_eq!(s.int_layers, 3);
    assert_eq!(s.fallback_layers, 0);
    assert!(s.int_macs > 0);
    assert!((s.integer_mac_fraction() - 1.0).abs() < 1e-12);
    // one prepack per weighted layer, Arc-shared thereafter
    assert_eq!(s.prepack_builds, 3);
    assert_eq!(s.prepack_hits, 0);
    // re-measuring the same config is memoized: nothing moves
    ev.measure_shared(0).unwrap();
    let s2 = ev.dispatch_stats();
    assert_eq!((s2.int_layers, s2.prepack_builds), (3, 3));
    // a config differing only in activation clipping shares every
    // prepacked panel: 3 cache hits, zero new builds
    let c0 = QuantConfig::from_index(0).unwrap();
    let other = (1..QuantConfig::SPACE_SIZE)
        .find(|&i| {
            let c = QuantConfig::from_index(i).unwrap();
            c.clip != c0.clip && QuantConfig { clip: c0.clip, ..c } == c0
        })
        .expect("space has a clip-only neighbour of config 0");
    ev.measure_shared(other).unwrap();
    let s3 = ev.dispatch_stats();
    assert_eq!(s3.prepack_builds, 3);
    assert_eq!(s3.prepack_hits, 3);
    assert_eq!(s3.int_layers, 6);
}

#[test]
fn grid_recovery_is_exact_for_all_schemes() {
    // the integer path's keystone: re-quantizing a fake-quant value
    // recovers its grid index exactly, for every scheme's params over a
    // representative range
    for scheme in ALL_SCHEMES {
        let p = scheme.params_from_range(-3.7, 5.3);
        let (lo, hi) = (p.qmin as i32, p.qmax as i32);
        for q in lo..=hi {
            let v = (q - p.zero_point) as f32 * p.scale;
            let rq = p.quantize(v);
            assert_eq!(rq, q, "{scheme:?}: grid point {q} recovered as {rq} (v = {v})");
        }
    }
}
