//! End-to-end integration over the real artifacts: zoo loading,
//! calibration, quantization, both evaluators, search, and the VTA path.
//!
//! Tests skip with a notice when `make artifacts` has not run.

use std::path::PathBuf;

use quantune::calib::{calibrate, CalibBackend};
use quantune::coordinator::{
    self, Evaluator, HloEvaluator, InterpEvaluator, OracleEvaluator, Quantune,
    GENERAL_SPACE_TAG,
};
use quantune::quant::{
    general_space, CalibCount, Clipping, Granularity, QuantConfig, Scheme, VtaConfig,
};
use quantune::runtime::Runtime;
use quantune::search::Trial;
use quantune::vta::VtaModel;
use quantune::zoo::{self, ZooModel};

fn artifacts() -> Option<PathBuf> {
    let dir = quantune::zoo::artifacts_dir();
    if dir.join("sqn_meta.json").exists() && dir.join("dataset_eval.qtd").exists() {
        Some(dir)
    } else {
        eprintln!("SKIP: no artifacts at {} (run `make artifacts`)", dir.display());
        None
    }
}

/// PJRT client, or a skip notice when the backend is unavailable (e.g.
/// the offline build links the stub `xla` crate).
fn runtime() -> Option<Runtime> {
    match Runtime::cpu() {
        Ok(rt) => Some(rt),
        Err(e) => {
            eprintln!("SKIP: PJRT unavailable ({e})");
            None
        }
    }
}

fn good_config() -> QuantConfig {
    QuantConfig {
        calib: CalibCount::C512,
        scheme: Scheme::Asymmetric,
        clip: Clipping::Kl,
        gran: Granularity::Channel,
        mixed: false,
        bias_correct: false,
    }
}

#[test]
fn all_available_models_load_and_validate() {
    let Some(dir) = artifacts() else { return };
    let models = zoo::load_all(&dir).unwrap();
    assert!(!models.is_empty());
    for m in &models {
        // graph validated on load; ABI covered; features well-formed
        assert_eq!(m.weights.order, m.graph.weight_names());
        let f = m.arch_features();
        assert_eq!(f.len(), zoo::ARCH_FEATURE_NAMES.len());
        assert!(f.iter().all(|x| x.is_finite()));
        assert!(m.fp32_top1 > 1.0 / 16.0, "{}: fp32 top1 at chance", m.name);
        assert!(m.graph.macs().unwrap() > 0);
    }
}

#[test]
fn interpreter_reproduces_training_accuracy() {
    let Some(dir) = artifacts() else { return };
    let q = Quantune::open(dir).unwrap();
    let model = q.load_model("sqn").unwrap();
    let interp = quantune::interp::Interpreter::new(&model.graph, model.weights_map());
    let mut hits = 0;
    let idx: Vec<usize> = (0..q.eval.n).collect();
    for chunk in idx.chunks(64) {
        let x = q.eval.batch(chunk);
        let logits = interp.forward(&x).unwrap();
        let preds = quantune::interp::argmax_batch(&logits);
        hits += preds
            .iter()
            .zip(&q.eval.labels_for(chunk))
            .filter(|(&p, &l)| p == l as usize)
            .count();
    }
    let top1 = hits as f64 / q.eval.n as f64;
    // the python trainer measured fp32_top1 on the same eval split with
    // jax; the rust interpreter must agree to float-noise level
    assert!(
        (top1 - model.fp32_top1).abs() < 0.01,
        "interp {top1} vs python {}",
        model.fp32_top1
    );
}

#[test]
fn hlo_and_interp_evaluators_agree() {
    let Some(dir) = artifacts() else { return };
    let q = Quantune::open(dir).unwrap();
    let model = q.load_model("sqn").unwrap();
    let Some(rt) = runtime() else { return };
    let mut hlo = HloEvaluator::new(
        &model, &rt, q.artifacts.clone(), &q.calib_pool, &q.eval, q.seed,
    );
    let mut interp = InterpEvaluator::new(&model, &q.calib_pool, &q.eval, q.seed);
    for cfg_idx in [0, good_config().index(), 41] {
        let a = hlo.measure(cfg_idx).unwrap();
        let b = interp.measure(cfg_idx).unwrap();
        assert!(
            (a - b).abs() <= 2.0 / q.eval.n as f64 + 1e-9,
            "config {cfg_idx}: hlo {a} vs interp {b}"
        );
    }
}

#[test]
fn good_config_recovers_fp32_accuracy() {
    let Some(dir) = artifacts() else { return };
    let q = Quantune::open(dir).unwrap();
    let model = q.load_model("sqn").unwrap();
    let Some(rt) = runtime() else { return };
    let mut hlo = HloEvaluator::new(
        &model, &rt, q.artifacts.clone(), &q.calib_pool, &q.eval, q.seed,
    );
    let acc = hlo.measure(good_config().index()).unwrap();
    assert!(
        acc >= model.fp32_top1 - 0.05,
        "well-calibrated int8 lost too much: {acc} vs fp32 {}",
        model.fp32_top1
    );
}

#[test]
fn mixed_precision_bypass_rows() {
    let Some(dir) = artifacts() else { return };
    let model = ZooModel::load(&dir, "sqn").unwrap();
    let bypass = coordinator::mixed_precision_bypass(&model, true);
    let qpoints = model.graph.quant_points();
    assert_eq!(bypass.len(), qpoints.len());
    // exactly three bypassed rows: input, first conv, final dense
    assert_eq!(bypass.iter().filter(|&&b| b).count(), 3);
    assert!(bypass[0], "input row must be bypassed");
    let none = coordinator::mixed_precision_bypass(&model, false);
    assert!(none.iter().all(|&b| !b));
}

#[test]
fn calibration_caches_differ_by_size() {
    let Some(dir) = artifacts() else { return };
    let q = Quantune::open(dir).unwrap();
    let model = q.load_model("sqn").unwrap();
    let c1 = calibrate(&model, &q.calib_pool, CalibCount::C1, &CalibBackend::Interp, 1)
        .unwrap();
    let c512 =
        calibrate(&model, &q.calib_pool, CalibCount::C512, &CalibBackend::Interp, 1)
            .unwrap();
    // more images -> wider observed ranges (monotone in the sample)
    let (lo1, hi1) = c1.hists[1].range();
    let (lo5, hi5) = c512.hists[1].range();
    assert!(lo5 <= lo1 && hi5 >= hi1);
    assert!(c512.hists[0].count > c1.hists[0].count);
}

#[test]
fn search_on_oracle_runs_all_algorithms() {
    let Some(dir) = artifacts() else { return };
    let q = Quantune::open(dir).unwrap();
    let model = q.load_model("sqn").unwrap();
    // synthetic oracle so this test does not depend on a prior sweep
    let table: Vec<f64> = (0..QuantConfig::SPACE_SIZE)
        .map(|i| {
            let c = QuantConfig::from_index(i).unwrap();
            0.4 + 0.1 * (c.clip == Clipping::Kl) as u8 as f64
                + 0.05 * (c.calib == CalibCount::C512) as u8 as f64
        })
        .collect();
    let space = general_space();
    for algo in ["random", "grid", "genetic", "xgb"] {
        let mut oracle = OracleEvaluator::new(table.clone());
        let trace = q
            .search(&model, &space, algo, &mut oracle, QuantConfig::SPACE_SIZE, 3)
            .unwrap();
        assert_eq!(trace.algo, algo);
        assert!(trace.best_score >= 0.55 - 1e-9, "{algo} missed the optimum");
        // the trace's best must be the history max
        let max = trace
            .trials
            .iter()
            .map(|t| t.score)
            .fold(f64::NEG_INFINITY, f64::max);
        assert_eq!(trace.best_score, max);
    }
}

#[test]
fn xgb_t_requires_then_uses_transfer() {
    let Some(dir) = artifacts() else { return };
    let mut q = Quantune::open(dir).unwrap();
    let model = q.load_model("sqn").unwrap();
    let table = vec![0.5; QuantConfig::SPACE_SIZE];
    let space = general_space();
    // no other-model records in a fresh in-memory db: xgb_t must refuse
    q.db = coordinator::Store::in_memory();
    let mut oracle = OracleEvaluator::new(table.clone());
    assert!(q.search(&model, &space, "xgb_t", &mut oracle, 4, 1).is_err());
    // seed the db with another model's records -> works
    for i in 0..QuantConfig::SPACE_SIZE {
        q.db
            .add(coordinator::Record::new(
                "mn".into(),
                GENERAL_SPACE_TAG.into(),
                i,
                0.5,
                0.0,
            ))
            .unwrap();
    }
    if q.artifacts.join("mn_meta.json").exists() {
        let mut oracle = OracleEvaluator::new(table);
        let trace = q.search(&model, &space, "xgb_t", &mut oracle, 4, 1).unwrap();
        assert_eq!(trace.trials.len(), 4);
    }
}

#[test]
fn vta_per_layer_beats_global_scale() {
    let Some(dir) = artifacts() else { return };
    let q = Quantune::open(dir).unwrap();
    let model = q.load_model("sqn").unwrap();
    let cfg = VtaConfig { calib: CalibCount::C64, clip: Clipping::Max, fusion: true };
    let cache =
        calibrate(&model, &q.calib_pool, cfg.calib, &CalibBackend::Interp, q.seed)
            .unwrap();
    let tuned = VtaModel::build(&model.graph, model.weights_map(), &cache.hists, &cfg)
        .unwrap();
    let global = VtaModel::build_global_scale(
        &model.graph,
        model.weights_map(),
        &cache.hists,
        true,
    )
    .unwrap();
    let eval_n = 256.min(q.eval.n);
    let idx: Vec<usize> = (0..eval_n).collect();
    let acc = |m: &VtaModel| {
        let mut hits = 0;
        for chunk in idx.chunks(64) {
            let x = q.eval.batch(chunk);
            let (_, preds, _) = m.forward(&x).unwrap();
            hits += preds
                .iter()
                .zip(&q.eval.labels_for(chunk))
                .filter(|(&p, &l)| p == l as usize)
                .count();
        }
        hits as f64 / eval_n as f64
    };
    let (at, ag) = (acc(&tuned), acc(&global));
    // Fig 8's claim: per-layer scales are dramatically better than the
    // single whole-network scale
    assert!(
        at > ag + 0.10,
        "per-layer {at} should beat global {ag} by a wide margin"
    );
}

#[test]
fn sweep_persists_to_database() {
    let Some(dir) = artifacts() else { return };
    let mut q = Quantune::open(dir).unwrap();
    q.db = coordinator::Store::in_memory();
    let model = q.load_model("sqn").unwrap();
    // tiny fake sweep via oracle (a full HLO sweep is exercised by the
    // benches; here we verify the bookkeeping)
    let space = general_space();
    let table: Vec<f64> =
        (0..QuantConfig::SPACE_SIZE).map(|i| i as f64 / 100.0).collect();
    let mut oracle = OracleEvaluator::new(table.clone());
    let got = q.sweep(&model, space.as_ref(), &mut oracle, false, |_, _| {}).unwrap();
    assert_eq!(got, table);
    assert!(q.db.has_full_sweep("sqn", GENERAL_SPACE_TAG, QuantConfig::SPACE_SIZE));
    // second call reuses the db (the empty oracle would error otherwise)
    let mut empty = OracleEvaluator::new(vec![]);
    let again =
        q.sweep(&model, space.as_ref(), &mut empty, false, |_, _| {}).unwrap();
    assert_eq!(again, table);
    let (best_cfg, best_acc) = q.db.best_general("sqn").unwrap();
    assert_eq!(best_cfg.index(), QuantConfig::SPACE_SIZE - 1);
    assert!((best_acc - (QuantConfig::SPACE_SIZE - 1) as f64 / 100.0).abs() < 1e-9);
}

#[test]
fn trial_type_is_plain_data() {
    let t = Trial::of(3, 0.5);
    let t2 = t;
    assert_eq!(t2.config, t.config);
    assert_eq!(t2.accuracy(), 0.5);
    assert!(t2.components.is_none());
}
