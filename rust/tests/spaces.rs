//! End-to-end guarantees of the ConfigSpace abstraction, on synthetic
//! models/datasets (no artifacts needed, so this suite is always active):
//!
//! - `xgb` (and the other algorithms) run unmodified over all three
//!   spaces through the same generic `Quantune::search` path;
//! - the layer-wise Pareto experiment recovers accuracy lost by a
//!   fragile layer while still quantizing at least half the layers;
//! - index <-> genome <-> features roundtrips hold for every space
//!   (the per-space unit tests cover the details; here we drive them
//!   through the shared trait object path the search driver uses).

use std::path::PathBuf;

use quantune::coordinator::{InterpEvaluator, Quantune, Store, DEVICES};
use quantune::data::{synthetic_dataset, Dataset};
use quantune::experiments;
use quantune::quant::{
    general_space, vta_space, BitWidth, CalibCount, Clipping, ConfigSpace,
    Granularity, QuantConfig, Scheme, SpaceRef, BINARY_WIDTHS,
};
use quantune::zoo::{synthetic_model, ZooModel};

fn fixtures() -> (ZooModel, Dataset, Dataset) {
    let model = synthetic_model(8, 4, 4, 3).unwrap();
    let calib = synthetic_dataset(32, 8, 8, 4, 4, 5);
    let eval = synthetic_dataset(96, 8, 8, 4, 4, 6);
    (model, calib, eval)
}

fn quantune_with(calib: &Dataset, eval: &Dataset) -> Quantune {
    Quantune {
        artifacts: PathBuf::from("."),
        calib_pool: calib.clone(),
        eval: eval.clone(),
        db: Store::in_memory(),
        seed: 1,
        device: DEVICES[1],
        seed_from_db: false,
    }
}

#[test]
fn roundtrips_through_the_trait_object() {
    let (model, calib, eval) = fixtures();
    let q = quantune_with(&calib, &eval);
    let base = QuantConfig {
        calib: CalibCount::C64,
        scheme: Scheme::Symmetric,
        clip: Clipping::Max,
        gran: Granularity::Tensor,
        mixed: false,
        bias_correct: false,
    };
    let spaces: Vec<SpaceRef> = vec![
        general_space(),
        vta_space(),
        q.layerwise_space(&model, base, 3, &BINARY_WIDTHS).unwrap(),
        q.layerwise_space(
            &model,
            base,
            3,
            &[BitWidth::Int4, BitWidth::Int8, BitWidth::Int16],
        )
        .unwrap(),
    ];
    for space in &spaces {
        let space: &dyn ConfigSpace = space.as_ref();
        let dim = space.features(0).unwrap().len();
        for i in 0..space.size() {
            let g = space.encode(i).unwrap();
            assert_eq!(space.decode(&g), i, "{} index {i}", space.tag());
            assert_eq!(space.features(i).unwrap().len(), dim, "{}", space.tag());
            space.plan(i).unwrap();
        }
    }
}

#[test]
fn general_space_roundtrips_all_288_and_extends_the_legacy_prefix() {
    // every config of the extended space survives index -> config ->
    // index and index -> genome -> index, and produces a distinct slug
    assert_eq!(QuantConfig::SPACE_SIZE, 288);
    let space = general_space();
    let mut slugs = std::collections::HashSet::new();
    for i in 0..QuantConfig::SPACE_SIZE {
        let cfg = QuantConfig::from_index(i).unwrap();
        assert_eq!(cfg.index(), i);
        let g = space.encode(i).unwrap();
        assert_eq!(space.decode(&g), i);
        assert!(slugs.insert(cfg.slug()), "duplicate slug {}", cfg.slug());
    }
    // the first 96 indices are exactly the legacy axes (no ACIQ, no
    // bias correction): a store recorded against the old space keeps
    // meaning the same configs under the new one
    for i in 0..QuantConfig::LEGACY_SPACE_SIZE {
        let cfg = QuantConfig::from_index(i).unwrap();
        assert!(!cfg.bias_correct, "legacy index {i} gained bias_correct");
        assert_ne!(cfg.clip, Clipping::Aciq, "legacy index {i} gained aciq");
    }
    // and every extension index carries at least one new axis
    for i in QuantConfig::LEGACY_SPACE_SIZE..QuantConfig::SPACE_SIZE {
        let cfg = QuantConfig::from_index(i).unwrap();
        assert!(
            cfg.bias_correct || cfg.clip == Clipping::Aciq,
            "extension index {i} is a legacy config"
        );
    }
}

#[test]
fn xgb_searches_all_three_spaces_through_one_generic_path() {
    let (model, calib, eval) = fixtures();
    let q = quantune_with(&calib, &eval);
    let base = QuantConfig {
        calib: CalibCount::C1,
        scheme: Scheme::Symmetric,
        clip: Clipping::Max,
        gran: Granularity::Tensor,
        mixed: false,
        bias_correct: false,
    };
    let spaces: Vec<SpaceRef> = vec![
        general_space(),
        vta_space(),
        q.layerwise_space(&model, base, 3, &BINARY_WIDTHS).unwrap(),
        q.layerwise_space(
            &model,
            base,
            2,
            &[BitWidth::Int4, BitWidth::Int8, BitWidth::Int16],
        )
        .unwrap(),
    ];
    for space in &spaces {
        let budget = 6.min(space.size());
        let mut ev = InterpEvaluator::new(&model, &calib, &eval, q.seed)
            .with_threads(1)
            .with_space(space.clone());
        let trace = q.search(&model, space, "xgb", &mut ev, budget, 7).unwrap();
        assert_eq!(trace.algo, "xgb", "{}", space.tag());
        assert_eq!(trace.trials.len(), budget, "{}", space.tag());
        assert!(trace.best_config < space.size(), "{}", space.tag());
        assert!(trace.trials.iter().all(|t| t.config < space.size()));
        // the trace's best must be the history max
        let max = trace
            .trials
            .iter()
            .map(|t| t.score)
            .fold(f64::NEG_INFINITY, f64::max);
        assert_eq!(trace.best_score, max, "{}", space.tag());
    }
}

#[test]
fn layerwise_pareto_beats_the_all_int8_base() {
    let rows = experiments::pareto_layerwise_synthetic().unwrap();
    assert_eq!(rows.len(), 8, "2^3 masks over the top-3 fragile layers");
    let base = rows.iter().find(|r| r.config == 0).unwrap();
    assert_eq!(base.fp32_layers, 0, "index 0 is the all-int8 base config");
    // every mask costs at least the all-int8 bytes
    assert!(rows.iter().all(|r| r.quant_bytes >= base.quant_bytes));
    // the planted fragile layer destroys the all-int8 agreement with
    // fp32, and un-quantizing it recovers it: some mask that still
    // quantizes >= 50% of the weighted layers must beat the base
    let winner = rows
        .iter()
        .filter(|r| 2 * (r.total_layers - r.fp32_layers) >= r.total_layers)
        .filter(|r| r.accuracy > base.accuracy)
        .max_by(|a, b| a.accuracy.partial_cmp(&b.accuracy).unwrap());
    assert!(
        winner.is_some(),
        "no >=50%-quantized mask beat the base accuracy {:.4} (rows: {:?})",
        base.accuracy,
        rows.iter().map(|r| (r.label.clone(), r.accuracy)).collect::<Vec<_>>()
    );
    // the base point and at least one improving mask are both measured,
    // so the frontier is non-trivial
    assert!(rows.iter().filter(|r| r.on_frontier).count() >= 2);
}

#[test]
fn byte_accounting_matches_a_hand_computed_sum() {
    use quantune::quant::{model_size_bytes_at, model_size_bytes_masked};
    // synthetic model: c1 [3,3,4,8] = 288 w + 8 b, c2 [3,3,8,8] = 576 w
    // + 8 b, d [8,4] = 32 w + 4 b
    let model = synthetic_model(8, 4, 4, 3).unwrap();
    let dims = |layer: &str| {
        let w = model.weights.get(&format!("{layer}_w")).unwrap();
        let b = model.weights.get(&format!("{layer}_b")).unwrap();
        (w.len(), b.len())
    };
    let widths = [BitWidth::Int4, BitWidth::Fp32, BitWidth::Int16];
    // per-layer, tensor granularity (1 scale group of 8 bytes):
    //   c1 int4: ceil(288/2) + 4*8 + 8 = 144 + 32 + 8        = 184
    //   c2 fp32: 4 * (576 + 8)                               = 2336
    //   d int16: 2*32 + 4*4 + 8 = 64 + 16 + 8                = 88
    let got =
        model_size_bytes_at(&model.graph, &dims, Granularity::Tensor, &widths);
    assert_eq!(got, 184 + 2336 + 88);
    // channel granularity prices one 8-byte scale group per channel
    let got_ch =
        model_size_bytes_at(&model.graph, &dims, Granularity::Channel, &widths);
    assert_eq!(got_ch, (184 + 8 * 7) + 2336 + (88 + 8 * 3));
    // the legacy mask accounting is exactly the {int8, fp32} projection
    let mask = [false, true, false];
    let as_widths = [BitWidth::Int8, BitWidth::Fp32, BitWidth::Int8];
    assert_eq!(
        model_size_bytes_masked(&model.graph, &dims, Granularity::Tensor, &mask),
        model_size_bytes_at(&model.graph, &dims, Granularity::Tensor, &as_widths),
    );
}

#[test]
fn radix_frontier_dominates_the_binary_masks() {
    // the ISSUE-4 acceptance shape: enumerating the same top-3 fragile
    // layers under the binary {int8, fp32} menu and the full {int4,
    // int8, int16, fp32} radix, at least one int4-bearing radix config
    // must dominate the best quantizing binary config on (size,
    // accuracy) -- and sit on the joint frontier
    let rows = experiments::pareto_radix_synthetic().unwrap();
    let binary: Vec<_> = rows.iter().filter(|r| r.space == "binary").collect();
    let radix: Vec<_> = rows.iter().filter(|r| r.space == "radix").collect();
    assert_eq!(binary.len(), 8, "2^3 binary masks");
    assert_eq!(radix.len(), 64, "4^3 radix assignments");
    // binary rows never use int4 (the menu forbids it)
    assert!(binary.iter().all(|r| r.int4_layers == 0));
    let dominator = radix
        .iter()
        .find(|r| r.int4_layers >= 1 && r.dominates_best_binary && r.on_frontier);
    assert!(
        dominator.is_some(),
        "no int4-bearing radix config dominates the best binary mask; radix rows: {:?}",
        radix
            .iter()
            .map(|r| (r.label.clone(), r.accuracy, r.quant_bytes))
            .collect::<Vec<_>>()
    );
    // the dominator is a genuine mixed-width point: it names an int4
    // override and still quantizes at least one layer
    let d = dominator.unwrap();
    assert!(d.label.contains(":int4"), "{}", d.label);
    assert!(d.fp32_layers < 3, "{}", d.label);
}

#[test]
fn layerwise_sweep_persists_under_its_own_tag() {
    let (model, calib, eval) = fixtures();
    let mut q = quantune_with(&calib, &eval);
    let base = QuantConfig {
        calib: CalibCount::C1,
        scheme: Scheme::Symmetric,
        clip: Clipping::Max,
        gran: Granularity::Tensor,
        mixed: false,
        bias_correct: false,
    };
    let space = q.layerwise_space(&model, base, 2, &BINARY_WIDTHS).unwrap();
    let ev = InterpEvaluator::new(&model, &calib, &eval, q.seed)
        .with_threads(1)
        .with_space(space.clone());
    let table = q
        .sweep_parallel(
            &model,
            space.as_ref(),
            &ev,
            false,
            &quantune::util::Pool::new(2),
            |_, _| {},
        )
        .unwrap();
    assert_eq!(table.len(), 4);
    assert!(q.db.has_full_sweep(&model.name, &space.tag(), 4));
    // the general-space table is untouched by layer-wise records
    assert!(!q.db.has_full_sweep(&model.name, "general", QuantConfig::SPACE_SIZE));
    assert!(q.db.records().iter().all(|r| r.space == space.tag()));
}
