//! End-to-end guarantees of the multi-objective layer and the NaN-safe
//! ranking it rides on (synthetic models only, so always active):
//!
//! - the Pareto-objectives experiment marks a frontier where no point is
//!   dominated on (accuracy, latency, bytes), checked independently;
//! - every strictly-positive weight setting picks a frontier point
//!   (a dominated point can never maximize a positive scalarization);
//! - a NaN accuracy record in the database degrades `best_for` and a
//!   full search instead of panicking;
//! - `search_objective` over the VTA space prices latency from cycle
//!   counts and prefers fused configs when accuracy ties.

use quantune::coordinator::{
    self, Database, InterpEvaluator, ObjectiveWeights, Quantune, Record,
    GENERAL_SPACE_TAG,
};
use quantune::experiments;
use quantune::quant::{general_space, vta_space, VtaConfig};
use quantune::search::Trial;

#[test]
fn objective_pareto_frontier_has_no_dominated_points() {
    let rows = experiments::pareto_objectives_synthetic().unwrap();
    assert_eq!(rows.len(), 8, "2^3 masks over the top-3 fragile layers");
    // independent dominance check (reimplemented, not the library's)
    let dominated = |i: usize| {
        rows.iter().enumerate().any(|(j, o)| {
            j != i
                && o.accuracy >= rows[i].accuracy
                && o.latency_ms <= rows[i].latency_ms
                && o.size_bytes <= rows[i].size_bytes
                && (o.accuracy > rows[i].accuracy
                    || o.latency_ms < rows[i].latency_ms
                    || o.size_bytes < rows[i].size_bytes)
        })
    };
    for (i, r) in rows.iter().enumerate() {
        assert_eq!(
            r.on_frontier,
            !dominated(i),
            "config {} frontier flag disagrees with independent dominance",
            r.config
        );
    }
    assert!(rows.iter().any(|r| r.on_frontier), "frontier cannot be empty");

    // strictly-positive weights can only pick non-dominated points
    let positive_slugs: Vec<String> = experiments::objective_weight_grid()
        .iter()
        .filter(|w| w.accuracy > 0.0 && w.latency > 0.0 && w.size > 0.0)
        .map(|w| w.slug())
        .collect();
    assert!(!positive_slugs.is_empty());
    for slug in &positive_slugs {
        let picked: Vec<_> =
            rows.iter().filter(|r| r.picked_by.contains(slug)).collect();
        assert_eq!(picked.len(), 1, "{slug} must pick exactly one config");
        assert!(
            picked[0].on_frontier,
            "{slug} picked dominated config {}",
            picked[0].config
        );
    }
}

#[test]
fn nan_database_record_degrades_best_for_and_search() {
    let mut q = Quantune::synthetic();
    let model = Quantune::synthetic_model().unwrap();
    // a poisoned record (NaN accuracy) next to real ones
    q.db = Database::in_memory();
    q.db.add(Record::new(model.name.clone(), GENERAL_SPACE_TAG.into(), 3, f64::NAN, 0.0));
    q.db.add(Record::new(model.name.clone(), GENERAL_SPACE_TAG.into(), 7, 0.8, 0.0));
    let (cfg, acc) = q.db.best_for(&model.name).expect("real record survives");
    assert_eq!(cfg.index(), 7);
    assert_eq!(acc, 0.8);

    // a search over the NaN-holed oracle table completes and never
    // reports a NaN-hole as best
    let space = general_space();
    let table = q.db.accuracy_table(&model.name, &space.tag(), space.size());
    assert!(table[3].is_nan() && !table[7].is_nan());
    let mut oracle = coordinator::OracleEvaluator::new(table);
    let trace = q.search(&model, &space, "grid", &mut oracle, 96, 5).unwrap();
    assert_eq!(trace.trials.len(), 96);
    assert_eq!(trace.best_config, 7);
    assert_eq!(trace.best_score, 0.8);

    // the genetic selector also survives NaN fitness end-to-end
    let trace = q.search(&model, &space, "genetic", &mut oracle, 32, 5).unwrap();
    assert_eq!(trace.trials.len(), 32);
    assert!(!trace.best_score.is_nan() || trace.trials.iter().all(|t: &Trial| t.score.is_nan()));
}

#[test]
fn vta_objective_search_prefers_fused_configs() {
    let q = Quantune::synthetic();
    let model = Quantune::synthetic_model().unwrap();
    let space = vta_space();
    let weights = ObjectiveWeights::parse("balanced").unwrap();
    let mut ev = InterpEvaluator::new(&model, &q.calib_pool, &q.eval, q.seed)
        .with_threads(1)
        .with_space(space.clone());
    let trace = q
        .search_objective(&model, &space, "grid", &mut ev, space.size(), 3, weights)
        .unwrap();
    assert_eq!(trace.trials.len(), 12);
    let best = trace.best_components.expect("objective run keeps components");
    // fusion changes cycles, not numerics: for the best config's (calib,
    // clip) twin pair, the fused one has the same accuracy and strictly
    // fewer cycles, so the winner must be fused
    let best_cfg = VtaConfig::from_index(trace.best_config).unwrap();
    assert!(best_cfg.fusion, "unfused config won a latency-aware objective");
    assert!(best.latency_ms > 0.0 && best.size_bytes > 0.0);
    // every trial's breakdown matches its own config's fusion pricing
    let fused_ms = trace
        .trials
        .iter()
        .find(|t| VtaConfig::from_index(t.config).unwrap().fusion)
        .and_then(|t| t.components)
        .unwrap()
        .latency_ms;
    let unfused_ms = trace
        .trials
        .iter()
        .find(|t| !VtaConfig::from_index(t.config).unwrap().fusion)
        .and_then(|t| t.components)
        .unwrap()
        .latency_ms;
    assert!(fused_ms < unfused_ms);
}
