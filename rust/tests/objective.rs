//! End-to-end guarantees of the multi-objective layer and the NaN-safe
//! ranking it rides on (synthetic models only, so always active):
//!
//! - the Pareto-objectives experiment marks a frontier where no point is
//!   dominated on (accuracy, latency, bytes), checked independently;
//! - every strictly-positive weight setting picks a frontier point
//!   (a dominated point can never maximize a positive scalarization);
//! - a NaN accuracy record in the database degrades `best_for` and a
//!   full search instead of panicking;
//! - `search_objective` over the VTA space prices latency from cycle
//!   counts and prefers fused configs when accuracy ties;
//! - a `Budget` (epsilon-constraint) NEVER lets an over-budget config
//!   reach the accuracy evaluator -- for the scalarized search and the
//!   NSGA-II Pareto search alike -- and an unsatisfiable budget is a
//!   descriptive error;
//! - the `pareto_search_synthetic` experiment's acceptance bar: NSGA-II
//!   recovers >= 80% of the exhaustive frontier's hypervolume from <=
//!   25% of the exhaustive evaluation budget.

use quantune::coordinator::{
    self, Budget, CostModel, Evaluator, InterpEvaluator, ObjectiveWeights, Quantune,
    Record, Store, GENERAL_SPACE_TAG,
};
use quantune::experiments;
use quantune::quant::{general_space, vta_space, VtaConfig};
use quantune::search::Trial;

/// Wraps an evaluator and records every config whose accuracy was
/// actually measured (the thing a budget must prevent for over-budget
/// configs).
struct CountingEvaluator<E> {
    inner: E,
    measured: Vec<usize>,
}

impl<E: Evaluator> Evaluator for CountingEvaluator<E> {
    fn measure(&mut self, config: usize) -> anyhow::Result<f64> {
        self.measured.push(config);
        self.inner.measure(config)
    }

    fn mean_measure_secs(&self) -> f64 {
        self.inner.mean_measure_secs()
    }
}

/// A latency budget over the VTA space admitting exactly the fused
/// half: the Budget plus the feasible config set, derived from the same
/// `CostModel` pricing `search_objective` will use.
fn fused_budget(q: &Quantune, space: &quantune::quant::SpaceRef) -> (Budget, Vec<usize>) {
    let cost = CostModel::build(
        &Quantune::synthetic_model().unwrap(),
        space.as_ref(),
        &q.device,
        quantune::vta::PYNQ_CLOCK_MHZ,
    )
    .unwrap();
    let fused_ms = (0..space.size())
        .map(|i| cost.cost(i).unwrap().latency_ms)
        .fold(f64::INFINITY, f64::min);
    let limits = Budget { max_latency_ms: Some(fused_ms), max_size_bytes: None };
    let feasible: Vec<usize> = (0..space.size())
        .filter(|&i| limits.admits(cost.cost(i).unwrap()))
        .collect();
    assert_eq!(feasible.len(), 6, "half the VTA space is fused");
    (limits, feasible)
}

#[test]
fn objective_pareto_frontier_has_no_dominated_points() {
    let rows = experiments::pareto_objectives_synthetic().unwrap();
    assert_eq!(rows.len(), 8, "2^3 masks over the top-3 fragile layers");
    // independent dominance check (reimplemented, not the library's)
    let dominated = |i: usize| {
        rows.iter().enumerate().any(|(j, o)| {
            j != i
                && o.accuracy >= rows[i].accuracy
                && o.latency_ms <= rows[i].latency_ms
                && o.size_bytes <= rows[i].size_bytes
                && (o.accuracy > rows[i].accuracy
                    || o.latency_ms < rows[i].latency_ms
                    || o.size_bytes < rows[i].size_bytes)
        })
    };
    for (i, r) in rows.iter().enumerate() {
        assert_eq!(
            r.on_frontier,
            !dominated(i),
            "config {} frontier flag disagrees with independent dominance",
            r.config
        );
    }
    assert!(rows.iter().any(|r| r.on_frontier), "frontier cannot be empty");

    // strictly-positive weights can only pick non-dominated points
    let positive_slugs: Vec<String> = experiments::objective_weight_grid()
        .iter()
        .filter(|w| w.accuracy > 0.0 && w.latency > 0.0 && w.size > 0.0)
        .map(|w| w.slug())
        .collect();
    assert!(!positive_slugs.is_empty());
    for slug in &positive_slugs {
        let picked: Vec<_> =
            rows.iter().filter(|r| r.picked_by.contains(slug)).collect();
        assert_eq!(picked.len(), 1, "{slug} must pick exactly one config");
        assert!(
            picked[0].on_frontier,
            "{slug} picked dominated config {}",
            picked[0].config
        );
    }
}

#[test]
fn nan_database_record_degrades_best_for_and_search() {
    let mut q = Quantune::synthetic();
    let model = Quantune::synthetic_model().unwrap();
    // a poisoned record (NaN accuracy) next to real ones
    q.db = Store::in_memory();
    q.db.add(Record::new(model.name.clone(), GENERAL_SPACE_TAG.into(), 3, f64::NAN, 0.0))
        .unwrap();
    q.db.add(Record::new(model.name.clone(), GENERAL_SPACE_TAG.into(), 7, 0.8, 0.0))
        .unwrap();
    let (cfg, acc) = q.db.best_general(&model.name).expect("real record survives");
    assert_eq!(cfg.index(), 7);
    assert_eq!(acc, 0.8);

    // a search over the NaN-holed oracle table completes and never
    // reports a NaN-hole as best
    let space = general_space();
    let table = q.db.accuracy_table(&model.name, &space.tag(), space.size());
    assert!(table[3].is_nan() && !table[7].is_nan());
    let mut oracle = coordinator::OracleEvaluator::new(table);
    let trace =
        q.search(&model, &space, "grid", &mut oracle, space.size(), 5).unwrap();
    assert_eq!(trace.trials.len(), space.size());
    assert_eq!(trace.best_config, 7);
    assert_eq!(trace.best_score, 0.8);

    // the genetic selector also survives NaN fitness end-to-end
    let trace = q.search(&model, &space, "genetic", &mut oracle, 32, 5).unwrap();
    assert_eq!(trace.trials.len(), 32);
    assert!(!trace.best_score.is_nan() || trace.trials.iter().all(|t: &Trial| t.score.is_nan()));
}

#[test]
fn budget_never_measures_over_budget_configs() {
    let q = Quantune::synthetic();
    let model = Quantune::synthetic_model().unwrap();
    let space = vta_space();
    let (limits, feasible) = fused_budget(&q, &space);
    let fused_ms = limits.max_latency_ms.unwrap();

    // scalarized search: grid proposes every config, but only feasible
    // ones may reach the inner evaluator
    let mut ev = CountingEvaluator {
        inner: coordinator::OracleEvaluator::new(vec![0.5; space.size()]),
        measured: Vec::new(),
    };
    let trace = q
        .search_objective(
            &model,
            &space,
            "grid",
            &mut ev,
            space.size(),
            3,
            ObjectiveWeights::parse("balanced").unwrap(),
            limits,
        )
        .unwrap();
    assert_eq!(trace.trials.len(), space.size(), "rejections still count as trials");
    let mut measured = ev.measured.clone();
    measured.sort_unstable();
    assert_eq!(measured, feasible, "exactly the feasible set was measured");
    for t in &trace.trials {
        let c = t.components.expect("objective trials carry components");
        if feasible.contains(&t.config) {
            assert!(!c.accuracy.is_nan());
        } else {
            // rejected before measurement: -inf score, NaN accuracy,
            // static costs still reported
            assert_eq!(t.score, f64::NEG_INFINITY);
            assert!(c.accuracy.is_nan());
            assert!(c.latency_ms > fused_ms);
        }
    }
    assert!(feasible.contains(&trace.best_config), "best must be feasible");
    assert!(VtaConfig::from_index(trace.best_config).unwrap().fusion);

    // the NSGA-II driver obeys the same constraint: nothing over budget
    // is ever measured, and the recovered front is feasible-only
    let mut ev2 = CountingEvaluator {
        inner: coordinator::OracleEvaluator::new(vec![0.5; space.size()]),
        measured: Vec::new(),
    };
    let (_, pareto) = q
        .search_pareto(
            &model,
            &space,
            &mut ev2,
            32,
            7,
            ObjectiveWeights::parse("balanced").unwrap(),
            limits,
        )
        .unwrap();
    for &c in &ev2.measured {
        assert!(feasible.contains(&c), "nsga2 measured over-budget config {c}");
    }
    assert!(!pareto.front.is_empty());
    for f in &pareto.front {
        assert!(feasible.contains(&f.config), "infeasible config on the front");
    }
}

#[test]
fn xgb_search_survives_budget_rejections() {
    // a budget-rejected trial scores -inf; the XGB fit must skip it
    // (an -inf label drives the base score to -inf and every
    // prediction to NaN, emptying the tie-break set -- a panic)
    let q = Quantune::synthetic();
    let model = Quantune::synthetic_model().unwrap();
    let space = vta_space();
    let (limits, _) = fused_budget(&q, &space);
    for seed in 0..4 {
        let mut ev = coordinator::OracleEvaluator::new(vec![0.5; space.size()]);
        let trace = q
            .search_objective(
                &model,
                &space,
                "xgb",
                &mut ev,
                space.size(),
                seed,
                ObjectiveWeights::parse("balanced").unwrap(),
                limits,
            )
            .unwrap();
        assert_eq!(trace.trials.len(), space.size());
        assert!(trace.best_score.is_finite());
        assert!(VtaConfig::from_index(trace.best_config).unwrap().fusion);
    }
}

#[test]
fn all_trials_over_budget_is_an_error_not_a_fake_best() {
    // a 1-trial constrained search whose only proposal is over budget
    // must refuse to report that config as "best" (it was never
    // measured: -inf score, NaN accuracy)
    let q = Quantune::synthetic();
    let model = Quantune::synthetic_model().unwrap();
    let space = vta_space();
    let (limits, feasible) = fused_budget(&q, &space);
    // grid's seed-dependent start offset covers both cases over a few
    // seeds: a feasible first proposal succeeds with a feasible best, an
    // infeasible one is a descriptive error
    let mut saw_error = false;
    let mut saw_success = false;
    for seed in 0..20 {
        let mut ev = coordinator::OracleEvaluator::new(vec![0.5; space.size()]);
        match q.search_objective(
            &model,
            &space,
            "grid",
            &mut ev,
            1,
            seed,
            ObjectiveWeights::parse("balanced").unwrap(),
            limits,
        ) {
            Ok(trace) => {
                assert!(feasible.contains(&trace.best_config));
                saw_success = true;
            }
            Err(e) => {
                let msg = e.to_string();
                assert!(msg.contains("over budget"), "{msg}");
                saw_error = true;
            }
        }
    }
    assert!(saw_error, "no seed started on an infeasible config");
    assert!(saw_success, "no seed started on a feasible config");
}

#[test]
fn unsatisfiable_budget_is_a_descriptive_error() {
    let q = Quantune::synthetic();
    let model = Quantune::synthetic_model().unwrap();
    let space = vta_space();
    let limits = Budget {
        max_latency_ms: Some(1e-12), // no config is this fast
        max_size_bytes: None,
    };
    let mut oracle = coordinator::OracleEvaluator::new(vec![0.5; space.size()]);
    let err = q
        .search_objective(
            &model,
            &space,
            "grid",
            &mut oracle,
            space.size(),
            3,
            ObjectiveWeights::parse("balanced").unwrap(),
            limits,
        )
        .unwrap_err()
        .to_string();
    assert!(err.contains("admits no config"), "{err}");
    assert!(err.contains("budget-lat-ms"), "{err}");
    let err2 = q
        .search_pareto(
            &model,
            &space,
            &mut oracle,
            space.size(),
            3,
            ObjectiveWeights::parse("balanced").unwrap(),
            limits,
        )
        .unwrap_err()
        .to_string();
    assert!(err2.contains("admits no config"), "{err2}");
}

/// The PR's acceptance bar: NSGA-II recovers >= 80% of the exhaustive
/// synthetic frontier (by hypervolume, the standard frontier-recovery
/// metric) while evaluating <= 25% of the space, and its reported
/// front/evaluation flags are internally consistent.
#[test]
fn pareto_search_recovers_frontier_within_quarter_budget() {
    let s = experiments::pareto_search_synthetic().unwrap();
    assert_eq!(s.exhaustive_evals, 64, "4 widths ^ 3 layers");
    assert!(
        s.nsga2_evals * 4 <= s.exhaustive_evals,
        "nsga2 used {} evaluations, over 25% of {}",
        s.nsga2_evals,
        s.exhaustive_evals
    );
    assert!(
        s.hv_ratio >= 0.8,
        "nsga2 recovered only {:.1}% of the exhaustive frontier hypervolume",
        s.hv_ratio * 100.0
    );
    assert!(s.true_front_fraction > 0.0, "no true-front config was found");
    // flag consistency: a config on the searched front was evaluated,
    // and the true-front flags agree with an independent dominance check
    for r in &s.rows {
        if r.on_nsga2_front {
            assert!(r.evaluated_by_nsga2, "front config {} never evaluated", r.config);
        }
    }
    let dominated = |i: usize| {
        s.rows.iter().enumerate().any(|(j, o)| {
            j != i
                && o.accuracy >= s.rows[i].accuracy
                && o.latency_ms <= s.rows[i].latency_ms
                && o.size_bytes <= s.rows[i].size_bytes
                && (o.accuracy > s.rows[i].accuracy
                    || o.latency_ms < s.rows[i].latency_ms
                    || o.size_bytes < s.rows[i].size_bytes)
        })
    };
    for (i, r) in s.rows.iter().enumerate() {
        assert_eq!(
            r.on_true_front,
            !dominated(i),
            "config {} true-front flag disagrees with independent dominance",
            r.config
        );
    }
}

#[test]
fn vta_objective_search_prefers_fused_configs() {
    let q = Quantune::synthetic();
    let model = Quantune::synthetic_model().unwrap();
    let space = vta_space();
    let weights = ObjectiveWeights::parse("balanced").unwrap();
    let mut ev = InterpEvaluator::new(&model, &q.calib_pool, &q.eval, q.seed)
        .with_threads(1)
        .with_space(space.clone());
    let trace = q
        .search_objective(
            &model,
            &space,
            "grid",
            &mut ev,
            space.size(),
            3,
            weights,
            coordinator::Budget::unlimited(),
        )
        .unwrap();
    assert_eq!(trace.trials.len(), 12);
    let best = trace.best_components.expect("objective run keeps components");
    // fusion changes cycles, not numerics: for the best config's (calib,
    // clip) twin pair, the fused one has the same accuracy and strictly
    // fewer cycles, so the winner must be fused
    let best_cfg = VtaConfig::from_index(trace.best_config).unwrap();
    assert!(best_cfg.fusion, "unfused config won a latency-aware objective");
    assert!(best.latency_ms > 0.0 && best.size_bytes > 0.0);
    // every trial's breakdown matches its own config's fusion pricing
    let fused_ms = trace
        .trials
        .iter()
        .find(|t| VtaConfig::from_index(t.config).unwrap().fusion)
        .and_then(|t| t.components)
        .unwrap()
        .latency_ms;
    let unfused_ms = trace
        .trials
        .iter()
        .find(|t| !VtaConfig::from_index(t.config).unwrap().fusion)
        .and_then(|t| t.components)
        .unwrap()
        .latency_ms;
    assert!(fused_ms < unfused_ms);
}
