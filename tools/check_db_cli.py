#!/usr/bin/env python3
"""Smoke-test the `quantune db` CLI against a fixture legacy database
(CI builds the release binary and then runs this script, so a broken
status/export/migrate path fails the build instead of shipping a CLI
that corrupts or strands trial data).

Exercised end to end, in a temp artifacts dir:
- a hand-written legacy database.json (null accuracy, a record missing
  its space tag, optional cost fields on and off) opens via `db status`
  on the json backend with the right record count;
- `db export` emits a parseable CSV (empty cells for NaN/absent) and
  `--format json` round-trips through a JSON parser with every record;
- `db migrate` replays the legacy file into the segmented trial log,
  retires database.json, and reports losslessness;
- after migration `db status` lands on the log backend with >= 1
  segment and the same record count, and `db export` is byte-identical
  to the pre-migration export;
- a second `db migrate` refuses to run (nothing left to migrate).

Usage: python3 tools/check_db_cli.py target/release/quantune
"""

import json
import shutil
import subprocess
import sys
import tempfile
from pathlib import Path

FIXTURE = """{"records": [
  {"model": "sqn", "space": "general", "config": 3, "accuracy": 0.71,
   "measure_secs": 0.5, "latency_ms": 2.25, "size_bytes": 123456,
   "device": "CPU(i7-8700)"},
  {"model": "sqn", "config": 9, "accuracy": null, "measure_secs": 0.4},
  {"model": "mn", "space": "vta", "config": 0, "accuracy": 0.66,
   "measure_secs": 1.25},
  {"model": "mn", "space": "general", "config": 5, "accuracy": 0.5,
   "measure_secs": 0.1, "fidelity": 0.25}
]}
"""
N_RECORDS = 4


def fail(msg: str) -> None:
    print(f"check_db_cli: FAIL: {msg}")
    sys.exit(1)


def run(cmd: list, expect_ok: bool = True) -> "subprocess.CompletedProcess":
    proc = subprocess.run(cmd, capture_output=True, text=True)
    shown = " ".join(cmd[1:])
    if expect_ok and proc.returncode != 0:
        fail(f"`{shown}` exited {proc.returncode}:\n{proc.stdout}{proc.stderr}")
    if not expect_ok and proc.returncode == 0:
        fail(f"`{shown}` was expected to fail but exited 0")
    return proc


def expect(haystack: str, needle: str, what: str) -> None:
    if needle not in haystack:
        fail(f"{what}: expected {needle!r} in output:\n{haystack}")


def main() -> None:
    if len(sys.argv) != 2:
        fail(f"usage: {sys.argv[0]} path/to/quantune")
    binary = Path(sys.argv[1])
    if not binary.exists():
        fail(f"binary {binary} not found (run `cargo build --release` first)")

    workdir = Path(tempfile.mkdtemp(prefix="quantune_db_cli_"))
    try:
        check(str(binary), workdir)
    finally:
        shutil.rmtree(workdir, ignore_errors=True)


def check(binary: str, artifacts: Path) -> None:
    (artifacts / "database.json").write_text(FIXTURE)
    base = [binary, "db"]
    at = ["--artifacts", str(artifacts)]

    # 1. status on the legacy backend (also the default db action)
    out = run(base + ["status"] + at).stdout
    expect(out, "backend: json", "pre-migration status")
    expect(out, f"records: {N_RECORDS}", "pre-migration status")
    expect(out, "general", "status space index")
    expect(out, "vta", "status space index")
    expect(out, "CPU(i7-8700)", "status device index")
    default_action = run([binary, "db"] + at).stdout
    if default_action != out:
        fail("`quantune db` (no action) must behave like `db status`")

    # 2. CSV export: header + one row per record, NaN/absent as empties
    csv_before = run(base + ["export"] + at).stdout
    lines = csv_before.strip().split("\n")
    header = (
        "seq,model,space,config,accuracy,measure_secs,latency_ms,size_bytes,"
        "device,fidelity"
    )
    if lines[0] != header:
        fail(f"csv header {lines[0]!r} != {header!r}")
    if len(lines) != 1 + N_RECORDS:
        fail(f"csv has {len(lines) - 1} data rows, want {N_RECORDS}")
    row = dict(zip(header.split(","), lines[2].split(",")))
    if row["accuracy"] != "":
        fail(f"null accuracy must export as an empty cell, got {row['accuracy']!r}")
    if row["space"] != "general":
        fail(f"missing space tag must default to general, got {row['space']!r}")
    if row["fidelity"] != "":
        fail(f"legacy record must export an empty fidelity cell, got {row['fidelity']!r}")
    racing_row = dict(zip(header.split(","), lines[4].split(",")))
    if racing_row["fidelity"] != "0.25":
        fail(f"partial-fidelity record must export 0.25, got {racing_row['fidelity']!r}")

    # 3. JSON export through --out (atomic write path) must parse
    json_path = artifacts / "export.json"
    run(base + ["export", "--format", "json", "--out", str(json_path)] + at)
    exported = json.loads(json_path.read_text())
    if not isinstance(exported, list) or len(exported) != N_RECORDS:
        fail(f"json export: want a list of {N_RECORDS} records, got {exported!r}")
    if exported[1]["accuracy"] is not None:
        fail("json export must keep the NaN accuracy as null")

    # 4. table view over the fixture's general-space records
    out = run(base + ["table", "--models", "sqn"] + at).stdout
    expect(out, "sqn x general", "db table")
    expect(out, "=> best config 3", "db table best line")

    # 5. migrate: legacy -> segmented log, verified lossless
    out = run(base + ["migrate"] + at).stdout
    expect(out, f"migrated {N_RECORDS} record(s) losslessly", "db migrate")
    if not (artifacts / "trials").is_dir():
        fail("migrate left no trials/ log directory")
    if (artifacts / "database.json").exists():
        fail("migrate must retire database.json")
    if not (artifacts / "database.json.migrated").exists():
        fail("migrate must keep the legacy file as database.json.migrated")

    # 6. the store now opens on the log backend with the same contents
    out = run(base + ["status"] + at).stdout
    expect(out, "backend: log", "post-migration status")
    expect(out, f"records: {N_RECORDS}", "post-migration status")
    expect(out, "segments: 1", "post-migration status")
    csv_after = run(base + ["export"] + at).stdout
    if csv_after != csv_before:
        fail(
            "export diverged across migration:\n"
            f"--- before ---\n{csv_before}--- after ---\n{csv_after}"
        )

    # 7. re-running migrate must refuse (no legacy file left)
    run(base + ["migrate"] + at, expect_ok=False)

    print(f"check_db_cli: OK ({N_RECORDS} records: json -> log, exports identical)")


if __name__ == "__main__":
    main()
