#!/usr/bin/env python3
"""Smoke-gate the analytical-PTQ-toolbox experiments (CI runs `cargo
bench --bench bench_figures -- pareto` first, which writes
results/aciq_synthetic.csv and results/pareto_radix_synthetic.csv;
this script then holds the new ACIQ / bias-correction / IP-allocator
axes to the PR's acceptance bar, so a regression that silently breaks
the analytical clipping threshold -- or lets the learned tuner fall
behind the measurement-free baseline -- fails the build).

Checks:
- `aciq_synthetic.csv`: the full clipping x bias-correction grid is
  present, and on the heavy-tailed synthetic model ACIQ's analytical
  threshold strictly beats Max clipping (plain rows, no bias
  correction) -- the paper-level claim the axis exists to reproduce;
- `pareto_radix_synthetic.csv`: exactly one radix row carries the IP
  width allocator's pick, it respects the byte budget (the best binary
  config's size, recomputed from the CSV), and the XGB tuner's pick at
  the same budget is feasible and no less accurate than the
  allocator's -- the learned search must beat-or-match its analytical
  baseline.

Usage: python3 tools/check_ptq_toolbox.py [results_dir]
Without an argument the default locations (results/, rust/results/)
are probed.
"""

import csv
import sys
from pathlib import Path

CANDIDATE_DIRS = [Path("results"), Path("rust/results")]
ACIQ_COLUMNS = ["clip", "bias_correct", "label", "top1"]
RADIX_COLUMNS = [
    "space", "config", "label", "int4_layers", "fp32_layers", "top1",
    "quant_bytes", "on_frontier", "dominates_best_binary", "ip_baseline",
    "xgb_best",
]


def fail(msg: str) -> None:
    print(f"check_ptq_toolbox: FAIL: {msg}")
    sys.exit(1)


def load(path: Path, expected_columns: list) -> list:
    if not path.exists():
        fail(f"{path} missing (run `cargo bench --bench bench_figures -- pareto`)")
    with path.open() as f:
        rows = list(csv.DictReader(f))
    if not rows:
        fail(f"{path}: no data rows")
    got = list(rows[0].keys())
    if got != expected_columns:
        fail(f"{path}: columns {got} != expected {expected_columns}")
    return rows


def check_aciq(path: Path) -> float:
    rows = load(path, ACIQ_COLUMNS)
    grid = {(r["clip"], r["bias_correct"]) for r in rows}
    want = {(c, b) for c in ("max", "kl", "aciq") for b in ("false", "true")}
    if grid != want:
        fail(f"{path}: clipping x bias_correct grid {sorted(grid)} != {sorted(want)}")
    for r in rows:
        if not 0.0 <= float(r["top1"]) <= 1.0:
            fail(f"{path}: top1 {r['top1']} out of [0,1] for {r['label']}")
    plain = {r["clip"]: float(r["top1"]) for r in rows if r["bias_correct"] == "false"}
    if plain["aciq"] <= plain["max"]:
        fail(
            "ACIQ's analytical threshold no longer beats Max clipping on the "
            f"heavy-tailed model (aciq {plain['aciq']} vs max {plain['max']})"
        )
    return plain["aciq"] - plain["max"]


def check_radix(path: Path) -> tuple:
    rows = load(path, RADIX_COLUMNS)
    binary = [r for r in rows if r["space"] == "binary"]
    radix = [r for r in rows if r["space"] == "radix"]
    if not binary or not radix:
        fail(f"{path}: need both binary and radix rows, got "
             f"{len(binary)}/{len(radix)}")
    # budget = best binary config's bytes, mirroring the experiment: the
    # all-fp32 mask (fp32_layers == layer count) is the unquantized
    # reference, not a deployment, so it cannot set the budget
    n_layers = max(int(r["fp32_layers"]) for r in binary)
    deployable = [r for r in binary if int(r["fp32_layers"]) < n_layers]
    if not deployable:
        fail(f"{path}: no deployable binary row (all masks are all-fp32?)")
    budget = min(
        (r for r in deployable),
        key=lambda r: (-float(r["top1"]), int(r["quant_bytes"])),
    )
    budget_bytes = int(budget["quant_bytes"])

    ip = [r for r in radix if r["ip_baseline"] == "true"]
    if len(ip) != 1:
        fail(f"{path}: expected exactly one ip_baseline radix row, got {len(ip)}")
    ip = ip[0]
    if int(ip["quant_bytes"]) > budget_bytes:
        fail(
            f"IP allocator pick {ip['label']} over budget: "
            f"{ip['quant_bytes']} > {budget_bytes} bytes"
        )

    xgb = [r for r in radix if r["xgb_best"] == "true"]
    if len(xgb) != 1:
        fail(f"{path}: expected exactly one xgb_best radix row, got {len(xgb)}")
    xgb = xgb[0]
    if int(xgb["quant_bytes"]) > budget_bytes:
        fail(
            f"XGB pick {xgb['label']} over budget: "
            f"{xgb['quant_bytes']} > {budget_bytes} bytes"
        )
    if float(xgb["top1"]) < float(ip["top1"]):
        fail(
            "the XGB tuner fell behind the measurement-free IP baseline "
            f"(xgb {xgb['label']}@{xgb['top1']} vs ip {ip['label']}@{ip['top1']})"
        )
    return ip, xgb, budget_bytes


def main() -> None:
    if len(sys.argv) > 2:
        fail(f"usage: {sys.argv[0]} [results_dir]")
    if len(sys.argv) == 2:
        base = Path(sys.argv[1])
    else:
        base = next(
            (d for d in CANDIDATE_DIRS if (d / "aciq_synthetic.csv").exists()),
            None,
        )
        if base is None:
            fail(
                f"no aciq_synthetic.csv in {[str(d) for d in CANDIDATE_DIRS]} "
                "(run `cargo bench --bench bench_figures -- pareto` first)"
            )
    margin = check_aciq(base / "aciq_synthetic.csv")
    ip, xgb, budget = check_radix(base / "pareto_radix_synthetic.csv")
    print(
        f"check_ptq_toolbox: OK (aciq beats max by {margin:.4f} top1; "
        f"ip baseline {ip['label']}@{ip['top1']} vs xgb {xgb['label']}@"
        f"{xgb['top1']} under {budget} bytes; {base})"
    )


if __name__ == "__main__":
    main()
