#!/usr/bin/env python3
"""Markdown link checker for the repo's cross-linked docs.

Verifies every relative markdown link `[text](target)` in the checked
files points at a file that exists (anchors `#...` are stripped; http(s)
and mailto links are skipped -- the CI runner is offline), and that
in-page anchors into other checked markdown files match a real heading.

Usage: python3 tools/check_links.py [file.md ...]
Defaults to the repo's cross-linked doc set when no files are given.
Exits non-zero listing every broken link.
"""

import re
import sys
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent
DEFAULT_DOCS = [
    "README.md",
    "ROADMAP.md",
    "rust/ARCHITECTURE.md",
    "rust/BENCHMARKS.md",
    "rust/SEARCH.md",
]

LINK_RE = re.compile(r"\[[^\]]*\]\(([^)]+)\)")
HEADING_RE = re.compile(r"^#{1,6}\s+(.*)$", re.MULTILINE)


def link_target(raw: str) -> str:
    """The path part of a link target: strips an optional quoted title
    (`[x](file.md "title")`) and an angle-bracket wrapper
    (`[x](<path with spaces>)`)."""
    raw = raw.strip()
    if raw.startswith("<") and ">" in raw:
        return raw[1 : raw.index(">")]
    return raw.split()[0] if raw.split() else raw


def slugify(heading: str) -> str:
    """GitHub-style anchor slug of a heading."""
    slug = heading.strip().lower()
    slug = re.sub(r"[^\w\- ]", "", slug)  # drop punctuation (&, :, ...)
    return slug.replace(" ", "-")


def strip_fences(text: str) -> str:
    """Drop fenced code blocks: a `# comment` inside a ```bash fence is
    not a heading, and an example link inside a fence is not a link."""
    out, fenced = [], False
    for line in text.splitlines():
        if line.lstrip().startswith("```"):
            fenced = not fenced
            continue
        if not fenced:
            out.append(line)
    return "\n".join(out)


def anchors_of(path: Path) -> set:
    """Anchor slugs of a file's headings, with GitHub's duplicate
    suffixes: the second 'Examples' heading is addressable as
    #examples-1, and only the first as #examples."""
    counts, anchors = {}, set()
    for h in HEADING_RE.findall(strip_fences(path.read_text())):
        slug = slugify(h)
        n = counts.get(slug, 0)
        anchors.add(slug if n == 0 else f"{slug}-{n}")
        counts[slug] = n + 1
    return anchors


def check(files) -> int:
    errors = []
    for name in files:
        src = REPO / name
        if not src.exists():
            errors.append(f"{name}: checked file itself is missing")
            continue
        for raw in LINK_RE.findall(strip_fences(src.read_text())):
            target = link_target(raw)
            if target.startswith(("http://", "https://", "mailto:")):
                continue
            raw, _, anchor = target.partition("#")
            dest = src if not raw else (src.parent / raw)
            if not dest.exists():
                errors.append(f"{name}: broken link -> {target}")
                continue
            if anchor and dest.suffix == ".md":
                if slugify(anchor) not in anchors_of(dest):
                    errors.append(f"{name}: broken anchor -> {target}")
    for e in errors:
        print(f"error: {e}", file=sys.stderr)
    if not errors:
        print(f"link check OK: {len(files)} file(s)")
    return 1 if errors else 0


if __name__ == "__main__":
    sys.exit(check(sys.argv[1:] or DEFAULT_DOCS))
