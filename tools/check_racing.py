#!/usr/bin/env python3
"""Smoke-gate the multi-fidelity racing experiment (CI runs `cargo
bench --bench bench_figures -- racing` first, which writes
results/racing_synthetic.csv; this script then holds the racing search
to the PR's acceptance bar, so a regression that silently stops
recovering the best config -- or stops being cheaper than exhaustive
measurement -- fails the build).

Checks, per stage of the experiment:
- `surface` (analytic oracle, ranking provably fidelity-invariant):
  racing MUST recover the exhaustive best score, at under 40% of the
  exhaustive evaluation cost;
- `interp` (live interpreter over the VTA space): the race must cost
  strictly less than the exhaustive sweep (charged by images actually
  interpreted) and crown a full-fidelity winner;
- both: sane row shape, positive trial counts, cost fractions
  consistent with the cost columns.

Usage: python3 tools/check_racing.py [results/racing_synthetic.csv]
Without an argument the default locations (results/, rust/results/)
are probed.
"""

import csv
import sys
from pathlib import Path

CANDIDATES = [
    Path("results/racing_synthetic.csv"),
    Path("rust/results/racing_synthetic.csv"),
]
EXPECTED_COLUMNS = [
    "stage", "algo", "exhaustive_best", "exhaustive_score", "racing_best",
    "racing_score", "recovered", "exhaustive_cost", "racing_cost",
    "cost_fraction", "trials", "full_trials",
]
SURFACE_COST_BAR = 0.4


def fail(msg: str) -> None:
    print(f"check_racing: FAIL: {msg}")
    sys.exit(1)


def load(path: Path) -> list:
    with path.open() as f:
        rows = list(csv.DictReader(f))
    if not rows:
        fail(f"{path}: no data rows")
    got = list(rows[0].keys())
    if got != EXPECTED_COLUMNS:
        fail(f"{path}: columns {got} != expected {EXPECTED_COLUMNS}")
    return rows


def check_common(row: dict) -> None:
    stage = row["stage"]
    if int(row["trials"]) <= 0:
        fail(f"{stage}: no trials ran")
    if int(row["full_trials"]) <= 0:
        fail(f"{stage}: no full-fidelity trial -- the winner was never confirmed")
    racing, exhaustive = float(row["racing_cost"]), float(row["exhaustive_cost"])
    if racing <= 0 or exhaustive <= 0:
        fail(f"{stage}: non-positive costs ({racing} vs {exhaustive})")
    frac = float(row["cost_fraction"])
    if abs(frac - racing / exhaustive) > 1e-3:
        fail(f"{stage}: cost_fraction {frac} inconsistent with {racing}/{exhaustive}")


def main() -> None:
    if len(sys.argv) > 2:
        fail(f"usage: {sys.argv[0]} [racing_synthetic.csv]")
    if len(sys.argv) == 2:
        path = Path(sys.argv[1])
    else:
        path = next((p for p in CANDIDATES if p.exists()), None)
        if path is None:
            fail(
                f"no racing_synthetic.csv in {[str(p) for p in CANDIDATES]} "
                "(run `cargo bench --bench bench_figures -- racing` first)"
            )
    rows = {r["stage"]: r for r in load(path)}
    for stage in ("surface", "interp"):
        if stage not in rows:
            fail(f"missing stage {stage!r}, got {sorted(rows)}")
        check_common(rows[stage])

    surface = rows["surface"]
    if surface["recovered"] != "true":
        fail(
            "surface stage did not recover the exhaustive best "
            f"(racing {surface['racing_best']}@{surface['racing_score']} vs "
            f"exhaustive {surface['exhaustive_best']}@{surface['exhaustive_score']})"
        )
    frac = float(surface["cost_fraction"])
    if frac >= SURFACE_COST_BAR:
        fail(f"surface stage cost fraction {frac} >= {SURFACE_COST_BAR}")

    interp = rows["interp"]
    interp_frac = float(interp["cost_fraction"])
    if interp_frac >= 1.0:
        fail(f"interp stage cost fraction {interp_frac} >= 1.0 -- racing cost "
             "as much as the exhaustive sweep")

    print(
        f"check_racing: OK (surface recovered best at {frac:.1%} of exhaustive "
        f"cost, interp raced at {interp_frac:.1%}; {path})"
    )


if __name__ == "__main__":
    main()
