#!/usr/bin/env python3
"""Sanity-check a BENCH_kernels.json emitted by `cargo bench --bench
bench_kernels` (CI runs the bench in --smoke mode and then this script,
so a bench that silently emits an empty or partial report fails the
build instead of shipping a hollow artifact).

Checks:
- the file parses and has the expected top-level structure;
- at least one shape was measured;
- every declared kernel variant has a row with a positive p50 in every
  shape (the four variants are pinned here on purpose: dropping one from
  the bench should be a deliberate, visible change);
- the i8-vs-f32 speedup field is present and positive.

Usage: python3 tools/check_bench_kernels.py BENCH_kernels.json
"""

import json
import sys

EXPECTED_VARIANTS = ["f32_scalar", "f32_blocked", "i8", "i4_packed"]


def fail(msg: str) -> None:
    print(f"check_bench_kernels: FAIL: {msg}")
    sys.exit(1)


def main() -> None:
    if len(sys.argv) != 2:
        fail(f"usage: {sys.argv[0]} BENCH_kernels.json")
    path = sys.argv[1]
    try:
        with open(path) as f:
            report = json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        fail(f"cannot parse {path}: {e}")

    variants = report.get("variants")
    if variants != EXPECTED_VARIANTS:
        fail(f"variants {variants!r} != expected {EXPECTED_VARIANTS!r}")

    shapes = report.get("shapes")
    if not isinstance(shapes, list) or not shapes:
        fail("no shapes measured")

    for shape in shapes:
        label = f"{shape.get('m')}x{shape.get('k')}x{shape.get('n')}"
        kernels = shape.get("kernels", {})
        for v in EXPECTED_VARIANTS:
            row = kernels.get(v)
            if not isinstance(row, dict):
                fail(f"shape {label}: missing kernel row {v!r}")
            for field in ("p50_ms", "mean_ms", "gmacs_per_s"):
                val = row.get(field)
                if not isinstance(val, (int, float)) or val <= 0:
                    fail(f"shape {label}: {v}.{field} = {val!r} (want > 0)")
        speedup = shape.get("speedup_i8_vs_f32")
        if not isinstance(speedup, (int, float)) or speedup <= 0:
            fail(f"shape {label}: speedup_i8_vs_f32 = {speedup!r} (want > 0)")

    print(
        f"check_bench_kernels: OK ({len(shapes)} shapes x "
        f"{len(EXPECTED_VARIANTS)} kernels, "
        f"i8 speedups {[round(s['speedup_i8_vs_f32'], 2) for s in shapes]})"
    )


if __name__ == "__main__":
    main()
