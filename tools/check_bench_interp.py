#!/usr/bin/env python3
"""Sanity-check a BENCH_interp.json emitted by `cargo bench --bench
bench_interp` (CI runs the bench in --smoke mode and then this script,
so a bench that silently emits an empty or partial report fails the
build instead of shipping a hollow artifact).

Checks:
- the file parses and has the expected top-level structure;
- both pinned models were measured (`syn8` conv-dominated, `dense_head`
  batch-1 dense-heavy -- dropping one should be a deliberate, visible
  change);
- every declared variant has positive p50/mean/ms-per-image timings in
  every row;
- the speedup fields are present and positive;
- the steady-state no-allocation contract held: zero pack calls per
  steady forward, and strictly fewer allocations than the per-call
  packing baseline.

Usage: python3 tools/check_bench_interp.py BENCH_interp.json
"""

import json
import sys

EXPECTED_VARIANTS = ["fq_f32", "int_repack", "int_steady"]
EXPECTED_MODELS = {"syn", "dense_head"}


def fail(msg: str) -> None:
    print(f"check_bench_interp: FAIL: {msg}")
    sys.exit(1)


def main() -> None:
    if len(sys.argv) != 2:
        fail(f"usage: {sys.argv[0]} BENCH_interp.json")
    path = sys.argv[1]
    try:
        with open(path) as f:
            report = json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        fail(f"cannot parse {path}: {e}")

    variants = report.get("variants")
    if variants != EXPECTED_VARIANTS:
        fail(f"variants {variants!r} != expected {EXPECTED_VARIANTS!r}")

    rows = report.get("rows")
    if not isinstance(rows, list) or not rows:
        fail("no rows measured")

    seen_models = set()
    for row in rows:
        label = f"{row.get('model')}@b{row.get('batch')}/{row.get('scheme')}"
        seen_models.add(row.get("model"))
        vrows = row.get("variants", {})
        for v in EXPECTED_VARIANTS:
            vrow = vrows.get(v)
            if not isinstance(vrow, dict):
                fail(f"row {label}: missing variant row {v!r}")
            for field in ("p50_ms", "mean_ms", "ms_per_image"):
                val = vrow.get(field)
                if not isinstance(val, (int, float)) or val <= 0:
                    fail(f"row {label}: {v}.{field} = {val!r} (want > 0)")
        for field in ("speedup_vs_repack", "speedup_vs_f32"):
            val = row.get(field)
            if not isinstance(val, (int, float)) or val <= 0:
                fail(f"row {label}: {field} = {val!r} (want > 0)")
        packs = row.get("pack_calls_per_fwd_steady")
        if packs != 0:
            fail(f"row {label}: pack_calls_per_fwd_steady = {packs!r} (want 0)")
        steady = row.get("allocs_per_fwd_steady")
        repack = row.get("allocs_per_fwd_repack")
        if not isinstance(steady, (int, float)) or not isinstance(repack, (int, float)):
            fail(f"row {label}: allocation counters missing")
        if steady >= repack:
            fail(
                f"row {label}: allocs_per_fwd_steady = {steady} not below "
                f"repack baseline {repack}"
            )

    missing = EXPECTED_MODELS - seen_models
    if missing:
        fail(f"pinned model(s) not measured: {sorted(missing)}")

    print(
        f"check_bench_interp: OK ({len(rows)} rows x "
        f"{len(EXPECTED_VARIANTS)} variants, speedups vs repack "
        f"{[round(r['speedup_vs_repack'], 2) for r in rows]})"
    )


if __name__ == "__main__":
    main()
