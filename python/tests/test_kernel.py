"""L1 Pallas kernels vs pure-jnp oracles.

The CORE correctness signal of the compile path: hypothesis sweeps
shapes/dtypes/parameters and asserts bit-exact agreement between the
Pallas kernels (interpret mode) and ref.py.
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

from compile.kernels.fake_quant import fake_quant
from compile.kernels.int8_gemm import int8_gemm_requant
from compile.kernels.ref import (
    fake_quant_ref,
    int8_gemm_requant_ref,
    requant_shift_ref,
)

SETTINGS = dict(max_examples=25, deadline=None)


@st.composite
def fq_case(draw):
    shape = tuple(
        draw(st.lists(st.integers(1, 9), min_size=1, max_size=4))
    )
    n = int(np.prod(shape))
    seed = draw(st.integers(0, 2**31 - 1))
    rng = np.random.default_rng(seed)
    x = rng.normal(0, draw(st.floats(0.1, 8.0)), size=shape).astype(np.float32)
    scale = draw(st.floats(1e-3, 1.0))
    zp = float(draw(st.integers(-128, 127)))
    return x, np.float32(scale), np.float32(zp)


@given(fq_case())
@settings(**SETTINGS)
def test_fake_quant_matches_ref(case):
    x, scale, zp = case
    got = fake_quant(jnp.asarray(x), scale, zp, -128.0, 127.0)
    want = fake_quant_ref(jnp.asarray(x), scale, zp, -128.0, 127.0)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_fake_quant_identity_when_scale_one_zp_zero():
    x = jnp.asarray(np.arange(-100, 100, dtype=np.float32))
    got = np.asarray(fake_quant(x, 1.0, 0.0, -128.0, 127.0))
    np.testing.assert_array_equal(got, np.round(np.asarray(x)))


def test_fake_quant_saturates():
    x = jnp.asarray(np.array([1e6, -1e6], np.float32))
    got = np.asarray(fake_quant(x, 1.0, 0.0, -128.0, 127.0))
    np.testing.assert_array_equal(got, [127.0, -128.0])


def test_fake_quant_odd_sizes_pad_correctly():
    # sizes around the (256, 128) block boundary
    for n in [1, 127, 128, 129, 255 * 128 + 1]:
        x = jnp.asarray(np.linspace(-4, 4, n, dtype=np.float32))
        got = fake_quant(x, 0.05, 3.0, -128.0, 127.0)
        want = fake_quant_ref(x, 0.05, 3.0, -128.0, 127.0)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@st.composite
def gemm_case(draw):
    m = draw(st.integers(1, 70))
    k = draw(st.integers(1, 70))
    n = draw(st.integers(1, 70))
    seed = draw(st.integers(0, 2**31 - 1))
    rng = np.random.default_rng(seed)
    a = rng.integers(-128, 128, size=(m, k), dtype=np.int32)
    b = rng.integers(-128, 128, size=(k, n), dtype=np.int32)
    bias = rng.integers(-4096, 4096, size=(n,), dtype=np.int32)
    mul = draw(st.integers(1, 8))
    shift = draw(st.integers(0, 16))
    return a, b, bias, mul, shift


@given(gemm_case())
@settings(**SETTINGS)
def test_int8_gemm_matches_ref(case):
    a, b, bias, mul, shift = case
    got = int8_gemm_requant(
        jnp.asarray(a), jnp.asarray(b), jnp.asarray(bias), mul, shift
    )
    want = int8_gemm_requant_ref(
        jnp.asarray(a), jnp.asarray(b), jnp.asarray(bias), mul, shift
    )
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_int8_gemm_output_in_int8_range():
    rng = np.random.default_rng(0)
    a = rng.integers(-128, 128, size=(33, 65), dtype=np.int32)
    b = rng.integers(-128, 128, size=(65, 17), dtype=np.int32)
    bias = np.zeros(17, np.int32)
    out = np.asarray(int8_gemm_requant(jnp.asarray(a), jnp.asarray(b),
                                       jnp.asarray(bias), 1, 7))
    assert out.min() >= -128 and out.max() <= 127


@pytest.mark.parametrize("acc,mul,shift,want", [
    (5, 1, 1, 3),       # 2.5 rounds (half away) to 3
    (-5, 1, 1, -2),     # -2.5 + 0.5 -> -2
    (4, 1, 2, 1),
    (3, 1, 0, 3),
    (1000, 1, 0, 127),  # clamps
    (-1000, 1, 0, -128),
])
def test_requant_shift_semantics(acc, mul, shift, want):
    got = int(requant_shift_ref(jnp.int32(acc), jnp.int32(mul), jnp.int32(shift)))
    assert got == want, (acc, mul, shift)
