"""AOT pipeline: HLO text emission and weight-container round-trip."""

import os
import tempfile

import numpy as np

import jax
import jax.numpy as jnp

from compile import aot, layers, model


def test_qtw_roundtrip():
    named = [
        ("a_w", np.random.default_rng(0).normal(size=(3, 3, 2, 4)).astype(np.float32)),
        ("a_b", np.zeros(4, np.float32)),
        ("scalar_ish", np.array([1.5], np.float32)),
    ]
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "w.qtw")
        aot.save_qtw(path, named)
        out = aot.load_qtw(path)
    assert set(out) == {"a_w", "a_b", "scalar_ish"}
    for k, v in named:
        np.testing.assert_array_equal(out[k], v)


def test_hlo_text_emission_small_model():
    """Lower the smallest model end to end and sanity-check the HLO text.

    The text must be parseable by the rust side: HloModule header plus an
    ENTRY computation with the full parameter list.
    """
    m = model.Model("sqn")
    w = m.init(seed=0)
    flat = layers.flatten_weights(m.nodes, w)
    x = jax.ShapeDtypeStruct((1, 32, 32, 3), jnp.float32)
    fspecs = [jax.ShapeDtypeStruct(t.shape, jnp.float32) for t in flat]
    lowered = jax.jit(m.fwd_fp32).lower(x, *fspecs)
    text = aot.to_hlo_text(lowered)
    assert text.startswith("HloModule")
    assert "ENTRY" in text
    # parameter count in the ENTRY computation: x + all weights
    # (fused sub-computations also use parameter() internally)
    entry = text[text.index("ENTRY"):]
    assert entry.count("parameter(") == 1 + len(flat)


def test_hlo_text_fq_has_act_params():
    m = model.Model("sqn")
    w = m.init(seed=0)
    flat = layers.flatten_weights(m.nodes, w)
    x = jax.ShapeDtypeStruct((1, 32, 32, 3), jnp.float32)
    ap = jax.ShapeDtypeStruct((len(m.quant_points), 5), jnp.float32)
    fspecs = [jax.ShapeDtypeStruct(t.shape, jnp.float32) for t in flat]
    lowered = jax.jit(m.fwd_fq(use_pallas=False)).lower(x, ap, *fspecs)
    text = aot.to_hlo_text(lowered)
    entry = text[text.index("ENTRY"):]
    assert entry.count("parameter(") == 2 + len(flat)
    # fake-quant lowers to round/clamp ops
    assert "round-nearest-even" in text or "round" in text
    assert "clamp" in text or "minimum" in text  # jnp.clip lowers to min/max


def test_manifest_constants_consistent():
    assert aot.EVAL_N <= 512
    assert aot.BATCH == 128
    assert set(aot.EPOCHS) == {"mn", "shn", "sqn", "gn", "rn18", "rn50"}
