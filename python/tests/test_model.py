"""L2 model graph tests: shapes, spec consistency, fq-vs-fp32 semantics."""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from compile import layers, model, specs


@pytest.fixture(scope="module", params=specs.MODELS)
def m(request):
    return model.Model(request.param)


@pytest.fixture(scope="module")
def weights_cache():
    return {}


def get_weights(m, cache):
    if m.name not in cache:
        cache[m.name] = m.init(seed=1)
    return cache[m.name]


def test_forward_shape(m, weights_cache):
    w = get_weights(m, weights_cache)
    x = jnp.zeros((2, *specs.INPUT_SHAPE), jnp.float32)
    logits = m.apply(w, x)
    assert logits.shape == (2, specs.NUM_CLASSES)
    assert np.isfinite(np.asarray(logits)).all()


def test_quant_points_cover_all_quant_ops(m):
    names = {n["name"]: n for n in m.nodes}
    for q in m.quant_points:
        assert q == "input" or names[q]["op"] in specs.QUANT_OPS
    # every quant-op output is covered exactly once
    want = 1 + sum(1 for n in m.nodes if n["op"] in specs.QUANT_OPS)
    assert len(m.quant_points) == want


def test_weight_abi_order_matches_spec(m, weights_cache):
    w = get_weights(m, weights_cache)
    flat = layers.flatten_weights(m.nodes, w)
    assert len(flat) == len(m.weight_names)
    rebuilt = layers.unflatten_weights(m.nodes, flat)
    for k in w:
        np.testing.assert_array_equal(np.asarray(w[k]), np.asarray(rebuilt[k]))


def test_fq_with_bypass_equals_fp32(m, weights_cache):
    """act_params with bypass=1 everywhere must reproduce fp32 exactly."""
    w = get_weights(m, weights_cache)
    x = jnp.asarray(
        np.random.default_rng(0).normal(0, 1, (2, *specs.INPUT_SHAPE)).astype(
            np.float32
        )
    )
    fp32 = m.apply(w, x)
    flat = layers.flatten_weights(m.nodes, w)
    fq = m.fwd_fq(use_pallas=False)(x, m.identity_act_params(), *flat)[0]
    np.testing.assert_array_equal(np.asarray(fp32), np.asarray(fq))


def test_fq_quantization_changes_logits(m, weights_cache):
    """A coarse grid must actually alter the logits."""
    w = get_weights(m, weights_cache)
    x = jnp.asarray(
        np.random.default_rng(1).normal(0, 1, (2, *specs.INPUT_SHAPE)).astype(
            np.float32
        )
    )
    flat = layers.flatten_weights(m.nodes, w)
    rows = len(m.quant_points)
    ap = np.zeros((rows, 5), np.float32)
    ap[:, 0] = 0.5  # very coarse scale
    ap[:, 2] = -128
    ap[:, 3] = 127
    fq = m.fwd_fq(use_pallas=False)(x, jnp.asarray(ap), *flat)[0]
    fp32 = m.apply(w, x)
    assert not np.allclose(np.asarray(fq), np.asarray(fp32))


def test_acts_capture_matches_quant_points(m, weights_cache):
    w = get_weights(m, weights_cache)
    x = jnp.zeros((1, *specs.INPUT_SHAPE), jnp.float32)
    flat = layers.flatten_weights(m.nodes, w)
    acts = m.fwd_acts(x, *flat)
    assert len(acts) == len(m.quant_points)
    # first capture is the input itself
    np.testing.assert_array_equal(np.asarray(acts[0]), np.asarray(x))


def test_pallas_and_jnp_fq_agree(weights_cache):
    """The pallas and jnp fake-quant paths are bit-identical on a model."""
    m = model.Model("sqn")
    w = get_weights(m, weights_cache)
    x = jnp.asarray(
        np.random.default_rng(2).normal(0, 1, (1, *specs.INPUT_SHAPE)).astype(
            np.float32
        )
    )
    flat = layers.flatten_weights(m.nodes, w)
    rows = len(m.quant_points)
    ap = np.zeros((rows, 5), np.float32)
    ap[:, 0] = 0.04
    ap[:, 2] = -128
    ap[:, 3] = 127
    a = m.fwd_fq(use_pallas=True)(x, jnp.asarray(ap), *flat)[0]
    b = m.fwd_fq(use_pallas=False)(x, jnp.asarray(ap), *flat)[0]
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_group_conv_channel_math():
    """ShuffleNet's grouped convs must keep channels divisible."""
    nodes = specs.build("shn")
    for n in nodes:
        if n["op"] == "conv":
            assert n["in_ch"] % n["groups"] == 0
            assert n["out_ch"] % n["groups"] == 0


def test_bn_fold_preserves_forward():
    """Folded BN weights reproduce the train-mode forward (population
    stats == batch stats when evaluated on the same single batch)."""
    m = model.Model("rn18")
    w = layers.init_weights(m.nodes, seed=3)
    bn = layers.init_bn(m.nodes)
    # make gamma/beta non-trivial
    key = jax.random.PRNGKey(0)
    for name in bn:
        key, k1, k2 = jax.random.split(key, 3)
        c = bn[name]["gamma"].shape[0]
        bn[name]["gamma"] = 1.0 + 0.1 * jax.random.normal(k1, (c,))
        bn[name]["beta"] = 0.1 * jax.random.normal(k2, (c,))
    x = jnp.asarray(
        np.random.default_rng(4).normal(0, 1, (64, *specs.INPUT_SHAPE)).astype(
            np.float32
        )
    )
    train_logits = layers.forward_train(m.nodes, w, bn, x)
    stats = layers.collect_bn_stats(m.nodes, w, bn, np.asarray(x), batch=64)
    folded = layers.fold_bn(m.nodes, w, bn, stats)
    folded_logits = layers.forward(m.nodes, folded, x, mode="fp32")
    np.testing.assert_allclose(
        np.asarray(train_logits), np.asarray(folded_logits), atol=2e-2, rtol=1e-2
    )
