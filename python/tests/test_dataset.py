"""Synthetic dataset: determinism, balance, format round-trip."""

import os
import tempfile

import numpy as np
from hypothesis import given, settings, strategies as st

from compile import dataset


def test_deterministic_generation():
    a_imgs, a_labels = dataset.generate(64, seed=9)
    b_imgs, b_labels = dataset.generate(64, seed=9)
    np.testing.assert_array_equal(a_imgs, b_imgs)
    np.testing.assert_array_equal(a_labels, b_labels)


def test_different_seeds_differ():
    a, _ = dataset.generate(16, seed=1)
    b, _ = dataset.generate(16, seed=2)
    assert not np.array_equal(a, b)


def test_labels_balanced():
    _, labels = dataset.generate(160, seed=3)
    counts = np.bincount(labels, minlength=dataset.NUM_CLASSES)
    assert (counts == 10).all()


def test_image_range_and_shape():
    imgs, labels = dataset.generate(8, seed=4)
    assert imgs.shape == (8, dataset.IMG, dataset.IMG, 3)
    assert imgs.dtype == np.uint8
    assert labels.max() < dataset.NUM_CLASSES


@given(st.integers(1, 40), st.integers(0, 1000))
@settings(max_examples=10, deadline=None)
def test_qtd_roundtrip(n, seed):
    imgs, labels = dataset.generate(n, seed)
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "x.qtd")
        dataset.save_qtd(path, imgs, labels)
        imgs2, labels2 = dataset.load_qtd(path)
    np.testing.assert_array_equal(imgs, imgs2)
    np.testing.assert_array_equal(labels, labels2)


def test_normalize_range():
    imgs, _ = dataset.generate(4, seed=5)
    x = dataset.normalize(imgs)
    assert x.dtype == np.float32
    assert x.min() >= -1.0 and x.max() <= 1.0


def test_classes_are_visually_distinct():
    """A trivial nearest-centroid classifier on raw pixels should beat
    chance comfortably -- the classes carry signal."""
    train_x, train_y = dataset.generate(320, seed=6)
    test_x, test_y = dataset.generate(160, seed=7)
    tx = dataset.normalize(train_x).reshape(len(train_y), -1)
    centroids = np.stack(
        [tx[train_y == c].mean(axis=0) for c in range(dataset.NUM_CLASSES)]
    )
    ex = dataset.normalize(test_x).reshape(len(test_y), -1)
    pred = np.argmin(
        ((ex[:, None, :] - centroids[None]) ** 2).sum(-1), axis=1
    )
    acc = (pred == test_y).mean()
    assert acc > 2.0 / dataset.NUM_CLASSES, f"centroid acc {acc}"
