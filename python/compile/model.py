"""L2 public model API: the six mini CNNs as JAX functions.

Thin facade over specs.py (architecture graphs) + layers.py (forward
engine). aot.py lowers these functions; train.py optimizes them.
"""

from __future__ import annotations

import jax.numpy as jnp

from . import layers, specs


class Model:
    """One mini CNN: spec + forward closures in the three AOT modes."""

    def __init__(self, name: str):
        assert name in specs.MODELS, name
        self.name = name
        self.full_name = specs.FULL_NAMES[name]
        self.nodes = specs.build(name)
        self.quant_points = specs.quant_points(self.nodes)
        self.weight_names = specs.weight_names(self.nodes)
        self.layers = specs.quantizable_layers(self.nodes)

    # ---- forward closures (flat-ABI, used for lowering) ----

    def fwd_fp32(self, x, *flat_weights):
        w = layers.unflatten_weights(self.nodes, list(flat_weights))
        return (layers.forward(self.nodes, w, x, mode="fp32"),)

    def fwd_fq(self, use_pallas=True):
        def fn(x, act_params, *flat_weights):
            w = layers.unflatten_weights(self.nodes, list(flat_weights))
            return (
                layers.forward(
                    self.nodes, w, x, mode="fq", act_params=act_params,
                    use_pallas=use_pallas,
                ),
            )

        return fn

    def fwd_acts(self, x, *flat_weights):
        w = layers.unflatten_weights(self.nodes, list(flat_weights))
        _, acts = layers.forward(self.nodes, w, x, mode="acts")
        return tuple(acts)

    # ---- convenience (dict-ABI, used for training/tests) ----

    def apply(self, weights, x):
        return layers.forward(self.nodes, weights, x, mode="fp32")

    def init(self, seed=0):
        return layers.init_weights(self.nodes, seed)

    def num_params(self, weights) -> int:
        return int(sum(v.size for v in weights.values()))

    def identity_act_params(self) -> jnp.ndarray:
        """act_params that make the fq graph equal the fp32 graph
        (bypass=1 everywhere); used by shape tests."""
        rows = len(self.quant_points)
        p = jnp.zeros((rows, 5), jnp.float32)
        return p.at[:, 0].set(1.0).at[:, 4].set(1.0)


def all_models():
    return [Model(m) for m in specs.MODELS]
