"""Build-time training of the six mini CNNs on the synthetic dataset.

The paper uses pretrained ImageNet models; we train our minis here, once,
as part of `make artifacts`. Plain Adam + cross-entropy (no optax in the
image). Deterministic given the seed.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from . import dataset, layers


def cross_entropy(logits, labels):
    logp = jax.nn.log_softmax(logits)
    return -jnp.mean(logp[jnp.arange(labels.shape[0]), labels])


def adam_init(params):
    """Adam state for any pytree of arrays."""
    zeros = jax.tree_util.tree_map(jnp.zeros_like, params)
    return {"m": zeros,
            "v": jax.tree_util.tree_map(jnp.zeros_like, params),
            "t": jnp.zeros((), jnp.int32)}


def adam_update(params, grads, state, lr=1e-3, b1=0.9, b2=0.999, eps=1e-8):
    t = state["t"] + 1
    tf = t.astype(jnp.float32)
    m = jax.tree_util.tree_map(lambda s, g: b1 * s + (1 - b1) * g, state["m"], grads)
    v = jax.tree_util.tree_map(lambda s, g: b2 * s + (1 - b2) * g**2, state["v"], grads)
    new_p = jax.tree_util.tree_map(
        lambda p, mm, vv: p
        - lr * (mm / (1 - b1**tf)) / (jnp.sqrt(vv / (1 - b2**tf)) + eps),
        params, m, v,
    )
    return new_p, {"m": m, "v": v, "t": t}


def accuracy(model, weights, imgs_u8, labels, batch=256):
    """Top-1 on u8 NHWC images."""
    hits = 0
    fwd = jax.jit(model.apply)
    for i in range(0, len(labels), batch):
        xb = jnp.asarray(dataset.normalize(imgs_u8[i : i + batch]))
        pred = np.asarray(jnp.argmax(fwd(weights, xb), axis=-1))
        hits += int((pred == labels[i : i + batch]).sum())
    return hits / len(labels)


def train_model(model, train_imgs, train_labels, epochs=14, batch=128,
                lr=2e-3, seed=0, log=print):
    """Train one mini CNN with per-conv batchnorm, then fold BN into the
    conv weights (the paper quantizes BN-folded models; so do we).
    Returns the folded, BN-free weight dict."""
    weights = layers.init_weights(model.nodes, seed=seed)
    bn = layers.init_bn(model.nodes)
    params = {"w": weights, "bn": bn}
    opt = adam_init(params)
    n = len(train_labels)
    rng = np.random.default_rng(seed)

    @jax.jit
    def step(params, opt, xb, yb, lr):
        def loss_fn(p):
            logits = layers.forward_train(model.nodes, p["w"], p["bn"], xb)
            return cross_entropy(logits, yb)

        loss, grads = jax.value_and_grad(loss_fn)(params)
        params, opt = adam_update(params, grads, opt, lr=lr)
        return params, opt, loss

    t0 = time.time()
    for ep in range(epochs):
        order = rng.permutation(n)
        # simple cosine decay
        cur_lr = lr * 0.5 * (1 + np.cos(np.pi * ep / epochs))
        losses = []
        for i in range(0, n - batch + 1, batch):
            idx = order[i : i + batch]
            xb = jnp.asarray(dataset.normalize(train_imgs[idx]))
            yb = jnp.asarray(train_labels[idx].astype(np.int32))
            params, opt, loss = step(params, opt, xb, yb, jnp.float32(cur_lr))
            losses.append(float(loss))
        log(
            f"  [{model.name}] epoch {ep + 1}/{epochs} "
            f"loss={np.mean(losses):.4f} ({time.time() - t0:.0f}s)"
        )

    # population statistics over (a slice of) the train set, then fold
    stats = layers.collect_bn_stats(
        model.nodes, params["w"], params["bn"],
        dataset.normalize(train_imgs[:2048]), batch=batch,
    )
    folded = layers.fold_bn(model.nodes, params["w"], params["bn"], stats)
    return {k: jnp.asarray(v) for k, v in folded.items()}
