"""AOT build: dataset -> trained weights -> HLO text artifacts.

Runs ONCE at build time (`make artifacts`); python never appears on the
rust request path afterwards. Emits into artifacts/:

  dataset_train.qtd / dataset_calib.qtd / dataset_eval.qtd
  {model}_weights.qtw            trained fp32 weights (rust-readable)
  {model}_meta.json              architecture spec + ABI + fp32 top1
  {model}_fp32.hlo.txt           fp32 forward, batch 128
  {model}_fq.hlo.txt             fake-quant forward, batch 128
  {model}_acts.hlo.txt           calibration instrumentation, batch 128
  {model}_fp32_b1.hlo.txt        single-image latency variants (Fig 9)
  {model}_fq_b1.hlo.txt
  kernel_fake_quant.hlo.txt      standalone L1 Pallas kernel artifacts
  kernel_int8_gemm.hlo.txt
  manifest.json

Interchange is HLO TEXT, not serialized protos: jax>=0.5 emits 64-bit
instruction ids that xla_extension 0.5.1 rejects; the text parser
reassigns ids (see /opt/xla-example/README.md).

The production fq artifacts lower the jnp fake-quant path: it is
bit-identical to the Pallas kernel (asserted by python/tests) and ~40x
faster under interpret-mode emulation on CPU PJRT. The Pallas kernels ship
as standalone artifacts exercised by rust tests/benches; on a real TPU the
fq graphs would lower with use_pallas=True unchanged.
"""

from __future__ import annotations

import argparse
import json
import os
import struct
import sys
import time

import numpy as np

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import dataset, layers, model, specs, train
from .kernels.fake_quant import fake_quant
from .kernels.int8_gemm import int8_gemm_requant

BATCH = 128
SEED = 20220205  # arXiv id of the paper
TRAIN_N = 4096
CALIB_N = 512  # calibration pool (paper: ImageNet train subset)
EVAL_N = 512  # held-out eval set (paper: ImageNet val)
EPOCHS = {"mn": 8, "shn": 8, "sqn": 8, "gn": 8, "rn18": 8, "rn50": 8}


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (the rust-side format)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def save_qtw(path: str, named: list[tuple[str, np.ndarray]]) -> None:
    """Weight container shared with rust/src/data (f32 only)."""
    with open(path, "wb") as f:
        f.write(b"QTW1")
        f.write(struct.pack("<I", len(named)))
        for name, arr in named:
            arr = np.asarray(arr, np.float32)
            nb = name.encode()
            f.write(struct.pack("<H", len(nb)))
            f.write(nb)
            f.write(struct.pack("<BB", 0, arr.ndim))
            for d in arr.shape:
                f.write(struct.pack("<I", d))
            f.write(arr.tobytes())


def load_qtw(path: str) -> dict[str, np.ndarray]:
    out = {}
    with open(path, "rb") as f:
        assert f.read(4) == b"QTW1"
        (n,) = struct.unpack("<I", f.read(4))
        for _ in range(n):
            (ln,) = struct.unpack("<H", f.read(2))
            name = f.read(ln).decode()
            dtype, ndim = struct.unpack("<BB", f.read(2))
            assert dtype == 0
            shape = struct.unpack(f"<{ndim}I", f.read(4 * ndim))
            size = int(np.prod(shape)) if ndim else 1
            out[name] = np.frombuffer(f.read(4 * size), np.float32).reshape(shape)
    return out


def build_datasets(outdir: str, force: bool, log=print):
    paths = {
        "train": os.path.join(outdir, "dataset_train.qtd"),
        "calib": os.path.join(outdir, "dataset_calib.qtd"),
        "eval": os.path.join(outdir, "dataset_eval.qtd"),
    }
    if not force and all(os.path.exists(p) for p in paths.values()):
        log("datasets: cached")
        return paths
    t0 = time.time()
    for split, n, seed in (
        ("train", TRAIN_N, SEED),
        ("calib", CALIB_N, SEED + 1),
        ("eval", EVAL_N, SEED + 2),
    ):
        imgs, labels = dataset.generate(n, seed)
        dataset.save_qtd(paths[split], imgs, labels)
    log(f"datasets: generated in {time.time() - t0:.0f}s")
    return paths


def lower_model(m: model.Model, weights: dict, outdir: str, log=print):
    flat = layers.flatten_weights(m.nodes, weights)
    flat_specs = [jax.ShapeDtypeStruct(w.shape, jnp.float32) for w in flat]
    nq = len(m.quant_points)

    def emit(fn, args, fname):
        lowered = jax.jit(fn).lower(*args)
        text = to_hlo_text(lowered)
        with open(os.path.join(outdir, fname), "w") as f:
            f.write(text)
        log(f"  wrote {fname} ({len(text) // 1024} KiB)")

    for b, suffix in ((BATCH, ""), (1, "_b1")):
        x = jax.ShapeDtypeStruct((b, 32, 32, 3), jnp.float32)
        ap = jax.ShapeDtypeStruct((nq, 5), jnp.float32)
        emit(m.fwd_fp32, (x, *flat_specs), f"{m.name}_fp32{suffix}.hlo.txt")
        emit(m.fwd_fq(use_pallas=False), (x, ap, *flat_specs),
             f"{m.name}_fq{suffix}.hlo.txt")
        if b == BATCH:
            emit(m.fwd_acts, (x, *flat_specs), f"{m.name}_acts.hlo.txt")


def lower_kernels(outdir: str, log=print):
    """Standalone L1 Pallas kernel artifacts (interpret-mode lowering)."""

    def fq_fn(x, params):
        return (fake_quant(x, params[0], params[1], params[2], params[3]),)

    emit_x = jax.ShapeDtypeStruct((BATCH, 32, 32, 16), jnp.float32)
    emit_p = jax.ShapeDtypeStruct((5,), jnp.float32)
    lowered = jax.jit(fq_fn).lower(emit_x, emit_p)
    with open(os.path.join(outdir, "kernel_fake_quant.hlo.txt"), "w") as f:
        f.write(to_hlo_text(lowered))
    log("  wrote kernel_fake_quant.hlo.txt")

    def gemm_fn(a, b, bias, ms):
        return (int8_gemm_requant(a, b, bias, ms[0], ms[1]),)

    a = jax.ShapeDtypeStruct((64, 96), jnp.int32)
    b = jax.ShapeDtypeStruct((96, 48), jnp.int32)
    bias = jax.ShapeDtypeStruct((48,), jnp.int32)
    ms = jax.ShapeDtypeStruct((2,), jnp.int32)
    lowered = jax.jit(gemm_fn).lower(a, b, bias, ms)
    with open(os.path.join(outdir, "kernel_int8_gemm.hlo.txt"), "w") as f:
        f.write(to_hlo_text(lowered))
    log("  wrote kernel_int8_gemm.hlo.txt")


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--models", default=",".join(specs.MODELS))
    args = ap.parse_args(argv)
    outdir = args.out
    os.makedirs(outdir, exist_ok=True)
    names = args.models.split(",")

    ds = build_datasets(outdir, args.force)
    train_imgs, train_labels = dataset.load_qtd(ds["train"])
    eval_imgs, eval_labels = dataset.load_qtd(ds["eval"])

    manifest = {"batch": BATCH, "seed": SEED, "models": {},
                "num_classes": specs.NUM_CLASSES,
                "eval_n": EVAL_N, "calib_n": CALIB_N}
    for name in names:
        m = model.Model(name)
        wpath = os.path.join(outdir, f"{name}_weights.qtw")
        mpath = os.path.join(outdir, f"{name}_meta.json")
        hlo_done = os.path.exists(os.path.join(outdir, f"{name}_fq.hlo.txt"))
        if not args.force and os.path.exists(wpath) and os.path.exists(mpath) and hlo_done:
            print(f"{name}: cached")
            meta = json.load(open(mpath))
            manifest["models"][name] = meta["fp32_top1"]
            continue

        print(f"{name}: training ({m.full_name})")
        weights = train.train_model(
            m, train_imgs, train_labels, epochs=EPOCHS[name], seed=SEED
        )
        top1 = train.accuracy(m, weights, eval_imgs, eval_labels)
        print(f"{name}: fp32 top1 = {top1 * 100:.2f}%")

        np_weights = {k: np.asarray(v) for k, v in weights.items()}
        save_qtw(wpath, [(k, np_weights[k]) for k in m.weight_names])
        meta = {
            "name": name,
            "full_name": m.full_name,
            "input_shape": list(specs.INPUT_SHAPE),
            "num_classes": specs.NUM_CLASSES,
            "batch": BATCH,
            "nodes": m.nodes,
            "quant_points": m.quant_points,
            "weight_names": m.weight_names,
            "layers": m.layers,
            "fp32_top1": top1,
        }
        json.dump(meta, open(mpath, "w"), indent=1)

        print(f"{name}: lowering HLO artifacts")
        lower_model(m, weights, outdir)
        manifest["models"][name] = top1

    lower_kernels(outdir)
    json.dump(manifest, open(os.path.join(outdir, "manifest.json"), "w"), indent=1)
    print("AOT build complete.")


if __name__ == "__main__":
    main()
