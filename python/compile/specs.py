"""Architecture specs for the six mini CNN models.

A spec is a JSON-serializable graph: a list of node dicts evaluated in
order. It is the single source of truth shared between the JAX forward
engine (layers.py) and the rust graph IR (rust/src/ir, rust/src/zoo): the
spec is exported verbatim into ``artifacts/{model}_meta.json``.

Node ops:
  input                                    (implicit, name "input")
  conv    {k, stride, pad, in_ch, out_ch, groups, act}
  pool    {kind: max|avg, k, stride, pad}
  gap     {}                               global average pool -> [N, C]
  add     {act}                            two inputs
  concat  {}                               n inputs, channel axis
  shuffle {groups}                         channel shuffle
  dense   {in_dim, out_dim}                after gap

``act`` is one of none|relu|relu6 and is fused into the producing node.

Quantization points (tensors that get their own activation profile +
scale): the input plus the outputs of conv, dense, add, concat, avg-pool
and gap nodes. max-pool and shuffle are value-preserving permutations /
max-selections, so in an int8 pipeline they run directly on the quantized
tensor of their producer (Glow does the same).

The six models mirror the paper's six ImageNet networks at mini scale:
same architectural motifs, 32x32x3 inputs, 16 classes.
"""

from __future__ import annotations

NUM_CLASSES = 16
INPUT_SHAPE = (32, 32, 3)

# ops whose outputs are quantization points
QUANT_OPS = ("conv", "dense", "add", "concat", "gap")
MODELS = ("mn", "shn", "sqn", "gn", "rn18", "rn50")
FULL_NAMES = {
    "mn": "MobileNetV2-mini",
    "shn": "ShuffleNetV1-mini",
    "sqn": "SqueezeNetV1-mini",
    "gn": "GoogLeNet-mini",
    "rn18": "ResNet18-mini",
    "rn50": "ResNet50-mini",
}


class B:
    """Tiny graph builder."""

    def __init__(self):
        self.nodes = []
        self._n = 0

    def _name(self, op):
        self._n += 1
        return f"{op}{self._n}"

    def node(self, op, inputs, **attrs):
        name = attrs.pop("name", None) or self._name(op)
        self.nodes.append({"name": name, "op": op, "inputs": list(inputs), **attrs})
        return name

    def conv(self, x, in_ch, out_ch, k=3, stride=1, pad=None, groups=1, act="relu"):
        if pad is None:
            pad = k // 2
        return self.node(
            "conv", [x], k=k, stride=stride, pad=pad, in_ch=in_ch, out_ch=out_ch,
            groups=groups, act=act,
        )

    def pool(self, x, kind, k=2, stride=2, pad=0):
        return self.node("pool", [x], kind=kind, k=k, stride=stride, pad=pad)

    def gap(self, x):
        return self.node("gap", [x])

    def add(self, a, b, act="none"):
        return self.node("add", [a, b], act=act)

    def concat(self, xs):
        return self.node("concat", xs)

    def shuffle(self, x, groups):
        return self.node("shuffle", [x], groups=groups)

    def dense(self, x, in_dim, out_dim):
        return self.node("dense", [x], in_dim=in_dim, out_dim=out_dim)


def mobilenet_mini() -> list[dict]:
    """MobileNetV2 motif: inverted residuals with depthwise 3x3, relu6."""
    b = B()
    x = b.conv("input", 3, 16, act="relu6")

    def inv_res(x, in_ch, out_ch, stride, t=4):
        mid = in_ch * t
        e = b.conv(x, in_ch, mid, k=1, act="relu6")
        d = b.conv(e, mid, mid, k=3, stride=stride, groups=mid, act="relu6")
        p = b.conv(d, mid, out_ch, k=1, act="none")
        if stride == 1 and in_ch == out_ch:
            return b.add(x, p)
        return p

    x = inv_res(x, 16, 24, 2)
    x = inv_res(x, 24, 24, 1)
    x = inv_res(x, 24, 40, 2)
    x = inv_res(x, 40, 40, 1)
    x = b.conv(x, 40, 128, k=1, act="relu6")
    x = b.gap(x)
    b.dense(x, 128, NUM_CLASSES)
    return b.nodes


def shufflenet_mini() -> list[dict]:
    """ShuffleNetV1 motif: grouped 1x1 convs + channel shuffle + depthwise."""
    g = 3
    b = B()
    x = b.conv("input", 3, 24, act="relu")

    def unit_down(x, in_ch, mid, out_branch):
        # stride-2 unit: concat(avgpool shortcut, transformed branch)
        c = b.conv(x, in_ch, mid, k=1, groups=g, act="relu")
        c = b.shuffle(c, g)
        c = b.conv(c, mid, mid, k=3, stride=2, groups=mid, act="none")
        c = b.conv(c, mid, out_branch, k=1, groups=g, act="none")
        s = b.pool(x, "avg", k=3, stride=2, pad=1)
        return b.concat([s, c])

    def unit(x, ch, mid):
        c = b.conv(x, ch, mid, k=1, groups=g, act="relu")
        c = b.shuffle(c, g)
        c = b.conv(c, mid, mid, k=3, stride=1, groups=mid, act="none")
        c = b.conv(c, mid, ch, k=1, groups=g, act="none")
        return b.add(x, c, act="relu")

    x = unit_down(x, 24, 30, 36)  # -> 24 + 36 = 60 ch, 16px
    x = unit(x, 60, 30)
    x = unit_down(x, 60, 60, 60)  # -> 120 ch, 8px
    x = unit(x, 120, 60)
    x = b.gap(x)
    b.dense(x, 120, NUM_CLASSES)
    return b.nodes


def squeezenet_mini() -> list[dict]:
    """SqueezeNet motif: fire modules (squeeze 1x1, expand 1x1 + 3x3)."""
    b = B()
    x = b.conv("input", 3, 32, act="relu")
    x = b.pool(x, "max", k=2, stride=2)

    def fire(x, in_ch, s, e):
        sq = b.conv(x, in_ch, s, k=1, act="relu")
        e1 = b.conv(sq, s, e, k=1, act="relu")
        e3 = b.conv(sq, s, e, k=3, act="relu")
        return b.concat([e1, e3])

    x = fire(x, 32, 8, 16)   # 32ch, 16px
    x = fire(x, 32, 8, 16)
    x = b.pool(x, "max", k=2, stride=2)
    x = fire(x, 32, 12, 24)  # 48ch, 8px
    x = fire(x, 48, 12, 24)
    x = b.pool(x, "max", k=2, stride=2)
    x = b.conv(x, 48, 64, k=1, act="relu")
    x = b.gap(x)
    b.dense(x, 64, NUM_CLASSES)
    return b.nodes


def googlenet_mini() -> list[dict]:
    """GoogLeNet motif: inception blocks with four parallel branches."""
    b = B()
    x = b.conv("input", 3, 32, act="relu")
    x = b.pool(x, "max", k=2, stride=2)  # 16px

    def inception(x, in_ch, c1, c3r, c3, c5r, c5, cp):
        b1 = b.conv(x, in_ch, c1, k=1, act="relu")
        b2 = b.conv(x, in_ch, c3r, k=1, act="relu")
        b2 = b.conv(b2, c3r, c3, k=3, act="relu")
        b3 = b.conv(x, in_ch, c5r, k=1, act="relu")
        b3 = b.conv(b3, c5r, c5, k=3, act="relu")
        b3 = b.conv(b3, c5, c5, k=3, act="relu")  # 5x5 as two 3x3s
        b4 = b.pool(x, "max", k=3, stride=1, pad=1)
        b4 = b.conv(b4, in_ch, cp, k=1, act="relu")
        return b.concat([b1, b2, b3, b4])

    x = inception(x, 32, 16, 12, 24, 6, 12, 12)    # -> 64
    x = inception(x, 64, 24, 16, 32, 8, 16, 16)    # -> 88
    x = b.pool(x, "max", k=2, stride=2)            # 8px
    x = inception(x, 88, 32, 24, 48, 12, 24, 24)   # -> 128
    x = b.gap(x)
    b.dense(x, 128, NUM_CLASSES)
    return b.nodes


def resnet18_mini() -> list[dict]:
    """ResNet basic-block motif."""
    b = B()
    x = b.conv("input", 3, 16, act="relu")

    def basic(x, in_ch, out_ch, stride):
        c = b.conv(x, in_ch, out_ch, k=3, stride=stride, act="relu")
        c = b.conv(c, out_ch, out_ch, k=3, act="none")
        if stride != 1 or in_ch != out_ch:
            x = b.conv(x, in_ch, out_ch, k=1, stride=stride, act="none")
        return b.add(x, c, act="relu")

    x = basic(x, 16, 16, 1)
    x = basic(x, 16, 16, 1)
    x = basic(x, 16, 32, 2)
    x = basic(x, 32, 32, 1)
    x = basic(x, 32, 64, 2)
    x = basic(x, 64, 64, 1)
    x = b.gap(x)
    b.dense(x, 64, NUM_CLASSES)
    return b.nodes


def resnet50_mini() -> list[dict]:
    """ResNet bottleneck-block motif (1x1 reduce, 3x3, 1x1 expand x4)."""
    b = B()
    x = b.conv("input", 3, 16, act="relu")

    def bottleneck(x, in_ch, mid, stride, project):
        out_ch = mid * 4
        c = b.conv(x, in_ch, mid, k=1, act="relu")
        c = b.conv(c, mid, mid, k=3, stride=stride, act="relu")
        c = b.conv(c, mid, out_ch, k=1, act="none")
        if project:
            x = b.conv(x, in_ch, out_ch, k=1, stride=stride, act="none")
        return b.add(x, c, act="relu")

    x = bottleneck(x, 16, 16, 1, True)    # -> 64
    x = bottleneck(x, 64, 16, 1, False)
    x = bottleneck(x, 64, 32, 2, True)    # -> 128
    x = bottleneck(x, 128, 32, 1, False)
    x = bottleneck(x, 128, 64, 2, True)   # -> 256
    x = bottleneck(x, 256, 64, 1, False)
    x = b.gap(x)
    b.dense(x, 256, NUM_CLASSES)
    return b.nodes


_BUILDERS = {
    "mn": mobilenet_mini,
    "shn": shufflenet_mini,
    "sqn": squeezenet_mini,
    "gn": googlenet_mini,
    "rn18": resnet18_mini,
    "rn50": resnet50_mini,
}


def build(model: str) -> list[dict]:
    return _BUILDERS[model]()


def quant_points(nodes: list[dict]) -> list[str]:
    """Names of tensors that get an activation-quantization profile.

    Row 0 of the activation-parameter array is always the network input.
    """
    pts = ["input"]
    pts += [n["name"] for n in nodes if n["op"] in QUANT_OPS]
    return pts


def weight_names(nodes: list[dict]) -> list[str]:
    """Flat weight tensor order shared with rust (conv/dense: w then b)."""
    out = []
    for n in nodes:
        if n["op"] in ("conv", "dense"):
            out += [f"{n['name']}_w", f"{n['name']}_b"]
    return out


def quantizable_layers(nodes: list[dict]) -> list[str]:
    """Weighted layers, in graph order (for mixed-precision first/last)."""
    return [n["name"] for n in nodes if n["op"] in ("conv", "dense")]
