"""L2 forward engine: evaluates an architecture spec as a JAX graph.

Three modes (one AOT artifact each, per model):

  fp32 -- plain float forward, weights as runtime inputs.
  fq   -- fake-quantized forward: after every quantization point the
          tensor passes through the L1 Pallas fake-quant kernel with
          runtime scale/zp/qmin/qmax/bypass parameters (one row of the
          ``act_params`` [L, 5] array per point). Weights arrive already
          fake-quantized by the rust coordinator.
  acts -- fp32 forward that also returns the tensor at every quantization
          point (Glow's "instrumented code" for calibration).

The parameter order of the lowered functions is the rust<->python ABI:
  fp32:  (x, w0, b0, w1, b1, ...)
  fq:    (x, act_params, w0, b0, ...)
  acts:  (x, w0, b0, ...)
with weights in specs.weight_names() order.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from . import specs
from .kernels.fake_quant import fake_quant
from .kernels.ref import fake_quant_ref


def _act(x, kind):
    if kind == "none":
        return x
    if kind == "relu":
        return jax.nn.relu(x)
    if kind == "relu6":
        return jnp.clip(x, 0.0, 6.0)
    raise ValueError(kind)


def _conv(x, w, b, attrs):
    s = attrs["stride"]
    p = attrs["pad"]
    out = jax.lax.conv_general_dilated(
        x,
        w,
        window_strides=(s, s),
        padding=((p, p), (p, p)),
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
        feature_group_count=attrs["groups"],
    )
    return _act(out + b[None, None, None, :], attrs["act"])


def _pool(x, attrs):
    k, s, p = attrs["k"], attrs["stride"], attrs["pad"]
    pads = ((0, 0), (p, p), (p, p), (0, 0))
    if attrs["kind"] == "max":
        return jax.lax.reduce_window(
            x, -jnp.inf, jax.lax.max, (1, k, k, 1), (1, s, s, 1), pads
        )
    ones = jnp.ones_like(x)
    summed = jax.lax.reduce_window(
        x, 0.0, jax.lax.add, (1, k, k, 1), (1, s, s, 1), pads
    )
    count = jax.lax.reduce_window(
        ones, 0.0, jax.lax.add, (1, k, k, 1), (1, s, s, 1), pads
    )
    return summed / count


def _shuffle(x, groups):
    n, h, w, c = x.shape
    x = x.reshape(n, h, w, groups, c // groups)
    x = jnp.swapaxes(x, 3, 4)
    return x.reshape(n, h, w, c)


def forward(
    nodes,
    weights,
    x,
    mode="fp32",
    act_params=None,
    use_pallas=True,
):
    """Evaluate the graph.

    weights: dict name -> array (HWIO convs, [in,out] dense, biases).
    mode: fp32 | fq | acts.
    act_params: [L, 5] f32 (scale, zp, qmin, qmax, bypass) for mode=fq.
    Returns logits (fp32/fq) or (logits, [acts...]) for mode=acts.
    """
    qpoints = specs.quant_points(nodes)
    fq_fn = fake_quant if use_pallas else fake_quant_ref

    def maybe_fq(name, t):
        if mode != "fq" or name not in qpoints:
            return t
        row = act_params[qpoints.index(name)]
        quantized = fq_fn(t, row[0], row[1], row[2], row[3])
        # bypass=1 keeps the tensor in fp32 (mixed precision / first-last)
        return jnp.where(row[4] > 0.5, t, quantized)

    captured = []
    env = {"input": maybe_fq("input", x)}
    if mode == "acts":
        captured.append(x)

    out_name = None
    for n in nodes:
        op = n["op"]
        ins = [env[i] for i in n["inputs"]]
        if op == "conv":
            t = _conv(ins[0], weights[f"{n['name']}_w"], weights[f"{n['name']}_b"], n)
        elif op == "pool":
            t = _pool(ins[0], n)
        elif op == "gap":
            t = jnp.mean(ins[0], axis=(1, 2))
        elif op == "add":
            t = _act(ins[0] + ins[1], n.get("act", "none"))
        elif op == "concat":
            t = jnp.concatenate(ins, axis=-1)
        elif op == "shuffle":
            t = _shuffle(ins[0], n["groups"])
        elif op == "dense":
            t = ins[0] @ weights[f"{n['name']}_w"] + weights[f"{n['name']}_b"]
        else:
            raise ValueError(op)
        if mode == "acts" and n["name"] in qpoints:
            captured.append(t)
        env[n["name"]] = maybe_fq(n["name"], t)
        out_name = n["name"]

    logits = env[out_name]
    if mode == "acts":
        return logits, captured
    return logits


# ---------------------------------------------------------------------------
# Batch-norm support (training only).
#
# The paper quantizes BN-folded pretrained models (Glow folds BN before
# profiling). We do the same: convs train with batchnorm (batch statistics),
# then population statistics are folded into conv weights/biases at export,
# so every downstream consumer (AOT artifacts, rust IR, quantizers) sees
# plain conv+bias graphs.
# ---------------------------------------------------------------------------

_BN_EPS = 1e-5


def forward_train(nodes, weights, bn, x):
    """fp32 forward with per-conv batchnorm using batch statistics.

    bn: dict name -> {"gamma": [C], "beta": [C]}.
    """

    def conv_bn(xin, n):
        name = n["name"]
        out = jax.lax.conv_general_dilated(
            xin,
            weights[f"{name}_w"],
            window_strides=(n["stride"], n["stride"]),
            padding=((n["pad"], n["pad"]), (n["pad"], n["pad"])),
            dimension_numbers=("NHWC", "HWIO", "NHWC"),
            feature_group_count=n["groups"],
        )
        mean = jnp.mean(out, axis=(0, 1, 2))
        var = jnp.var(out, axis=(0, 1, 2))
        out = (out - mean) / jnp.sqrt(var + _BN_EPS)
        out = out * bn[name]["gamma"] + bn[name]["beta"]
        return _act(out, n["act"])

    env = {"input": x}
    out_name = None
    for n in nodes:
        op = n["op"]
        ins = [env[i] for i in n["inputs"]]
        if op == "conv":
            t = conv_bn(ins[0], n)
        elif op == "pool":
            t = _pool(ins[0], n)
        elif op == "gap":
            t = jnp.mean(ins[0], axis=(1, 2))
        elif op == "add":
            t = _act(ins[0] + ins[1], n.get("act", "none"))
        elif op == "concat":
            t = jnp.concatenate(ins, axis=-1)
        elif op == "shuffle":
            t = _shuffle(ins[0], n["groups"])
        elif op == "dense":
            t = ins[0] @ weights[f"{n['name']}_w"] + weights[f"{n['name']}_b"]
        else:
            raise ValueError(op)
        env[n["name"]] = t
        out_name = n["name"]
    return env[out_name]


def collect_bn_stats(nodes, weights, bn, imgs_f32, batch=128):
    """Population BN statistics: average batch mean/var over the train set.

    Returns dict name -> (mean, var) as numpy arrays.
    """
    import numpy as np

    agg = {}

    @jax.jit
    def one_batch(xb):
        stats = {}

        def conv_bn(xin, n):
            name = n["name"]
            out = jax.lax.conv_general_dilated(
                xin,
                weights[f"{name}_w"],
                window_strides=(n["stride"], n["stride"]),
                padding=((n["pad"], n["pad"]), (n["pad"], n["pad"])),
                dimension_numbers=("NHWC", "HWIO", "NHWC"),
                feature_group_count=n["groups"],
            )
            mean = jnp.mean(out, axis=(0, 1, 2))
            var = jnp.var(out, axis=(0, 1, 2))
            stats[name] = (mean, var)
            out = (out - mean) / jnp.sqrt(var + _BN_EPS)
            out = out * bn[name]["gamma"] + bn[name]["beta"]
            return _act(out, n["act"])

        env = {"input": xb}
        for n in nodes:
            op = n["op"]
            ins = [env[i] for i in n["inputs"]]
            if op == "conv":
                t = conv_bn(ins[0], n)
            elif op == "pool":
                t = _pool(ins[0], n)
            elif op == "gap":
                t = jnp.mean(ins[0], axis=(1, 2))
            elif op == "add":
                t = _act(ins[0] + ins[1], n.get("act", "none"))
            elif op == "concat":
                t = jnp.concatenate(ins, axis=-1)
            elif op == "shuffle":
                t = _shuffle(ins[0], n["groups"])
            elif op == "dense":
                t = ins[0] @ weights[f"{n['name']}_w"] + weights[f"{n['name']}_b"]
            else:
                raise ValueError(op)
            env[n["name"]] = t
        return stats

    nb = 0
    for i in range(0, len(imgs_f32) - batch + 1, batch):
        stats = one_batch(jnp.asarray(imgs_f32[i : i + batch]))
        nb += 1
        for k, (m, v) in stats.items():
            m, v = np.array(m), np.array(v)
            if k not in agg:
                agg[k] = [m, v]
            else:
                agg[k][0] += m
                agg[k][1] += v
    return {k: (m / nb, v / nb) for k, (m, v) in agg.items()}


def fold_bn(nodes, weights, bn, stats):
    """Fold batchnorm into conv weights/biases; returns plain weights.

    w' = w * gamma / sqrt(var + eps)   (per output channel)
    b' = beta - gamma * mean / sqrt(var + eps)
    """
    out = dict(weights)
    for n in nodes:
        if n["op"] != "conv":
            continue
        name = n["name"]
        gamma = bn[name]["gamma"]
        beta = bn[name]["beta"]
        mean, var = stats[name]
        scale = gamma / jnp.sqrt(jnp.asarray(var) + _BN_EPS)
        out[f"{name}_w"] = weights[f"{name}_w"] * scale[None, None, None, :]
        out[f"{name}_b"] = beta - jnp.asarray(mean) * scale
    return out


def init_bn(nodes):
    bn = {}
    for n in nodes:
        if n["op"] == "conv":
            c = n["out_ch"]
            bn[n["name"]] = {
                "gamma": jnp.ones((c,), jnp.float32),
                "beta": jnp.zeros((c,), jnp.float32),
            }
    return bn


def init_weights(nodes, seed=0):
    """He-normal init, biases zero. Returns dict name -> np-backed array."""
    key = jax.random.PRNGKey(seed)
    weights = {}
    for n in nodes:
        if n["op"] == "conv":
            k, cin, cout, g = n["k"], n["in_ch"], n["out_ch"], n["groups"]
            key, sub = jax.random.split(key)
            fan_in = k * k * (cin // g)
            w = jax.random.normal(sub, (k, k, cin // g, cout)) * jnp.sqrt(
                2.0 / fan_in
            )
            weights[f"{n['name']}_w"] = w.astype(jnp.float32)
            weights[f"{n['name']}_b"] = jnp.zeros((cout,), jnp.float32)
        elif n["op"] == "dense":
            din, dout = n["in_dim"], n["out_dim"]
            key, sub = jax.random.split(key)
            w = jax.random.normal(sub, (din, dout)) * jnp.sqrt(2.0 / din)
            weights[f"{n['name']}_w"] = w.astype(jnp.float32)
            weights[f"{n['name']}_b"] = jnp.zeros((dout,), jnp.float32)
    return weights


def flatten_weights(nodes, weights):
    """Weights as a flat list in the rust<->python ABI order."""
    return [weights[name] for name in specs.weight_names(nodes)]


def unflatten_weights(nodes, flat):
    return dict(zip(specs.weight_names(nodes), flat))
