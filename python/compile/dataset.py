"""Synthetic 16-class image dataset (the ImageNet stand-in).

The paper calibrates and evaluates on ImageNet, which is unavailable here.
This module generates a deterministic, procedurally-rendered 32x32 RGB
dataset whose classes are (shape x texture) combinations. It is learnable by
small CNNs to high accuracy, while producing long-tailed activation
distributions in trained networks -- the property that makes calibration
sample count and clipping interact the way the paper reports.

Classes: shape in {circle, square, triangle, cross} x texture in
{solid, stripes, checker, radial}. Nuisance factors (not class-defining):
color, position, scale, rotation, background gradient, pixel noise.

File format ``.qtd`` (shared with the rust ``data`` module)::

    magic   b"QTD1"
    u32     n_images
    u32     height
    u32     width
    u32     channels
    u8[n]   labels
    u8[n*h*w*c]  pixels (NHWC, row-major)

All integers little-endian.
"""

from __future__ import annotations

import struct

import numpy as np

NUM_CLASSES = 16
IMG = 32
SHAPES = ("circle", "square", "triangle", "cross")
TEXTURES = ("solid", "stripes", "checker", "radial")


def class_name(label: int) -> str:
    return f"{SHAPES[label // 4]}_{TEXTURES[label % 4]}"


def _shape_mask(shape: str, rng: np.random.Generator) -> np.ndarray:
    """Binary mask for a randomly-placed instance of ``shape``."""
    yy, xx = np.mgrid[0:IMG, 0:IMG].astype(np.float32)
    cx = rng.uniform(10, IMG - 10)
    cy = rng.uniform(10, IMG - 10)
    r = rng.uniform(6.5, 11.0)
    theta = rng.uniform(0, 2 * np.pi)
    # rotate coordinates about the center
    xr = (xx - cx) * np.cos(theta) + (yy - cy) * np.sin(theta)
    yr = -(xx - cx) * np.sin(theta) + (yy - cy) * np.cos(theta)
    if shape == "circle":
        return (xr**2 + yr**2) <= r**2
    if shape == "square":
        return (np.abs(xr) <= r * 0.82) & (np.abs(yr) <= r * 0.82)
    if shape == "triangle":
        # upward triangle: inside three half-planes
        h = r * 1.2
        return (yr >= -h * 0.5) & (yr + 2.4 * xr <= h) & (yr - 2.4 * xr <= h)
    if shape == "cross":
        w = r * 0.38
        return ((np.abs(xr) <= w) & (np.abs(yr) <= r)) | (
            (np.abs(yr) <= w) & (np.abs(xr) <= r)
        )
    raise ValueError(shape)


def _texture(texture: str, rng: np.random.Generator) -> np.ndarray:
    """Texture field in [0,1], (IMG, IMG)."""
    yy, xx = np.mgrid[0:IMG, 0:IMG].astype(np.float32)
    phase = rng.uniform(0, 2 * np.pi)
    if texture == "solid":
        return np.ones((IMG, IMG), np.float32)
    if texture == "stripes":
        freq = rng.uniform(0.9, 1.4)
        return 0.5 + 0.5 * np.sin(freq * (xx + yy * 0.15) + phase)
    if texture == "checker":
        p = rng.integers(3, 5)
        return (((xx // p) + (yy // p)) % 2).astype(np.float32)
    if texture == "radial":
        cx, cy = rng.uniform(12, 20, size=2)
        d = np.sqrt((xx - cx) ** 2 + (yy - cy) ** 2)
        return 0.5 + 0.5 * np.cos(d * rng.uniform(0.55, 0.8) + phase)
    raise ValueError(texture)


def render_image(label: int, rng: np.random.Generator) -> np.ndarray:
    """Render one u8 HWC image of the given class."""
    shape = SHAPES[label // 4]
    texture = TEXTURES[label % 4]

    # background: low-frequency gradient + noise
    yy, xx = np.mgrid[0:IMG, 0:IMG].astype(np.float32) / IMG
    gdir = rng.uniform(-1, 1, size=2)
    bg_base = rng.uniform(0.1, 0.5, size=3)
    bg = bg_base[None, None, :] + 0.25 * (gdir[0] * xx + gdir[1] * yy)[:, :, None]

    mask = _shape_mask(shape, rng).astype(np.float32)
    tex = _texture(texture, rng)
    fg_color = rng.uniform(0.45, 1.0, size=3)
    fg_color2 = rng.uniform(0.0, 0.35, size=3)
    fg = tex[:, :, None] * fg_color[None, None, :] + (1 - tex[:, :, None]) * fg_color2[
        None, None, :
    ]

    img = bg * (1 - mask[:, :, None]) + fg * mask[:, :, None]
    img += rng.normal(0, 0.03, size=img.shape)
    return (np.clip(img, 0, 1) * 255).astype(np.uint8)


def generate(n: int, seed: int) -> tuple[np.ndarray, np.ndarray]:
    """Generate ``n`` images with balanced class labels. Returns (x, y)."""
    rng = np.random.default_rng(seed)
    labels = np.arange(n) % NUM_CLASSES
    rng.shuffle(labels)
    imgs = np.stack([render_image(int(l), rng) for l in labels])
    return imgs, labels.astype(np.uint8)


def save_qtd(path: str, imgs: np.ndarray, labels: np.ndarray) -> None:
    assert imgs.dtype == np.uint8 and labels.dtype == np.uint8
    n, h, w, c = imgs.shape
    with open(path, "wb") as f:
        f.write(b"QTD1")
        f.write(struct.pack("<IIII", n, h, w, c))
        f.write(labels.tobytes())
        f.write(imgs.tobytes())


def load_qtd(path: str) -> tuple[np.ndarray, np.ndarray]:
    with open(path, "rb") as f:
        magic = f.read(4)
        assert magic == b"QTD1", f"bad magic {magic!r}"
        n, h, w, c = struct.unpack("<IIII", f.read(16))
        labels = np.frombuffer(f.read(n), np.uint8)
        imgs = np.frombuffer(f.read(n * h * w * c), np.uint8).reshape(n, h, w, c)
    return imgs, labels


def normalize(imgs: np.ndarray) -> np.ndarray:
    """u8 NHWC -> f32 in [-1, 1]; identical to the rust side."""
    return imgs.astype(np.float32) / 127.5 - 1.0
