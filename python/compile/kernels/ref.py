"""Pure-jnp oracles for the Pallas kernels.

These are the correctness references: pytest (python/tests/test_kernel.py)
sweeps shapes/dtypes with hypothesis and asserts the Pallas kernels match
these to float tolerance / bit-exactness. The rust interpreters
(rust/src/interp, rust/src/vta) implement the same arithmetic; parity is
checked end-to-end through the HLO artifacts.

Rounding convention: round-half-to-even everywhere (jnp.round == XLA
RoundNearestEven); the rust side uses f32::round_ties_even.
"""

from __future__ import annotations

import jax.numpy as jnp


def fake_quant_ref(x, scale, zp, qmin, qmax):
    """Quantize-dequantize ``x`` through an affine int grid.

    q  = clamp(round(x / scale + zp), qmin, qmax)
    x' = (q - zp) * scale

    All of scale/zp/qmin/qmax are f32 scalars (zp/qmin/qmax hold integer
    values); x is any-shape f32. This parameterization covers all four
    paper schemes -- they differ only in how scale/zp/qmin/qmax are
    computed from the tensor range (done on the rust side).
    """
    q = jnp.clip(jnp.round(x / scale + zp), qmin, qmax)
    return (q - zp) * scale


def requant_shift_ref(acc, mul, shift):
    """VTA-style fixed-point requantization of an i32 accumulator.

    y = clamp((acc * mul + (1 << (shift-1))) >> shift, -128, 127)

    ``mul`` and ``shift`` are i32 scalars; the rounding term makes the
    arithmetic right shift round-half-away-from-zero (VTA ALU behaviour).
    """
    acc = acc.astype(jnp.int32) * mul
    rounding = jnp.right_shift(jnp.left_shift(jnp.int32(1), shift), jnp.int32(1))
    y = jnp.right_shift(acc + rounding, shift)
    return jnp.clip(y, -128, 127).astype(jnp.int32)


def int8_gemm_requant_ref(a, b, bias, mul, shift):
    """int8 GEMM with int32 accumulate + power-of-two requantization.

    a: [M, K] i8-range values (i32 storage accepted), b: [K, N], bias: [N]
    i32. Returns [M, N] i32 holding int8-range values.
    """
    acc = jnp.dot(
        a.astype(jnp.int8), b.astype(jnp.int8), preferred_element_type=jnp.int32
    )
    acc = acc + bias[None, :].astype(jnp.int32)
    return requant_shift_ref(acc, mul, shift)
