"""Pallas kernel: affine fake-quantization (quantize-dequantize).

This is the L1 hot-spot of the fake-quant evaluation path: every
quantization point in the L2 model graph passes its activation tensor
through this kernel. The kernel is written TPU-shaped -- last dimension
tiled to the 128-wide lane dimension, second-to-last to 8 sublanes, params
broadcast from a small operand -- but executed with ``interpret=True``
(CPU PJRT cannot run Mosaic custom-calls; see DESIGN.md
§Hardware-Adaptation).

TPU resource estimate (for DESIGN.md §9): block (256, 128) f32 in/out =
256 KiB VMEM for double-buffered in+out; pure-VPU elementwise (no MXU),
~6 vector ops per element -> bandwidth-bound, roofline ~= HBM BW.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Rows per grid step. 128 lanes is the TPU vector width; 256 rows keeps the
# block within a comfortable VMEM budget while amortizing grid overhead.
_BLOCK_ROWS = 256
_LANES = 128


def _fq_kernel(params_ref, x_ref, o_ref):
    scale = params_ref[0]
    zp = params_ref[1]
    qmin = params_ref[2]
    qmax = params_ref[3]
    q = jnp.clip(jnp.round(x_ref[...] / scale + zp), qmin, qmax)
    o_ref[...] = (q - zp) * scale


def fake_quant(x, scale, zp, qmin, qmax, *, interpret=True):
    """Quantize-dequantize ``x`` (any shape, f32) through an affine grid.

    scale/zp/qmin/qmax are f32 scalars (runtime values, not trace-time
    constants -- the rust coordinator feeds them per configuration).
    Matches kernels.ref.fake_quant_ref bit-for-bit.
    """
    orig_shape = x.shape
    n = x.size
    # Flatten and pad to a (rows, 128) tile multiple.
    cols = _LANES
    rows = -(-n // cols)
    pad_rows = -(-rows // _BLOCK_ROWS) * _BLOCK_ROWS
    xf = jnp.ravel(x)
    xf = jnp.pad(xf, (0, pad_rows * cols - n))
    xf = xf.reshape(pad_rows, cols)

    params = jnp.stack(
        [
            jnp.asarray(scale, jnp.float32),
            jnp.asarray(zp, jnp.float32),
            jnp.asarray(qmin, jnp.float32),
            jnp.asarray(qmax, jnp.float32),
        ]
    )

    out = pl.pallas_call(
        _fq_kernel,
        grid=(pad_rows // _BLOCK_ROWS,),
        in_specs=[
            pl.BlockSpec((4,), lambda i: (0,)),
            pl.BlockSpec((_BLOCK_ROWS, cols), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((_BLOCK_ROWS, cols), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((pad_rows, cols), jnp.float32),
        interpret=interpret,
    )(params, xf)
    return out.reshape(-1)[:n].reshape(orig_shape)
