"""Pallas kernel: int8 GEMM with int32 accumulation + pow2 requantization.

This is the TPU adaptation of the paper's integer-only (VTA) compute path:
the VTA GEMM core is a 16x16 int8 systolic array with an int32 accumulator
register file and a shift-based ALU for requantization. On TPU the same
structure maps to MXU tiles with an int32 VMEM scratch accumulator and a
fused shift-round-clamp epilogue -- expressed here with a K-innermost grid
and `scratch_shapes=[pltpu.VMEM(...)]`.

Executed with ``interpret=True`` on CPU PJRT (see DESIGN.md). The rust VTA
simulator (rust/src/vta) implements identical arithmetic; parity is
asserted by rust/tests/runtime_integration.rs through the
``int8_gemm.hlo.txt`` artifact.

TPU resource estimate (real-TPU tiles 128x128): A + B i8 blocks 32 KiB,
acc i32 block 64 KiB -> 96 KiB/stage double-buffered = 192 KiB VMEM;
MXU-bound, int8 throughput ~2x bf16 roofline.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

_BM = 32  # output tile rows (128 on real TPU; small for interpret speed)
_BN = 32  # output tile cols
_BK = 32  # contraction tile


def _gemm_kernel(a_ref, b_ref, bias_ref, shifts_ref, o_ref, acc_ref):
    """Grid = (M/_BM, N/_BN, K/_BK); K is the innermost (fastest) axis."""
    k = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    a = a_ref[...].astype(jnp.int32)
    b = b_ref[...].astype(jnp.int32)
    acc_ref[...] += jax.lax.dot_general(
        a, b, (((1,), (0,)), ((), ())), preferred_element_type=jnp.int32
    )

    @pl.when(k == nk - 1)
    def _epilogue():
        mul = shifts_ref[0]
        shift = shifts_ref[1]
        acc = (acc_ref[...] + bias_ref[...][None, :]) * mul
        rounding = jnp.right_shift(jnp.left_shift(jnp.int32(1), shift), 1)
        y = jnp.right_shift(acc + rounding, shift)
        o_ref[...] = jnp.clip(y, -128, 127)


def _pad_to(x, m, axis):
    pad = (-x.shape[axis]) % m
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


def int8_gemm_requant(a, b, bias, mul, shift, *, interpret=True):
    """C[M,N] = requant_pow2(A[M,K] @ B[K,N] + bias[N], mul, shift).

    a/b hold int8-range values in i32 storage (the xla crate cannot build
    i8 literals); bias/mul/shift are i32. Output is i32 in int8 range.
    Matches kernels.ref.int8_gemm_requant_ref exactly.
    """
    m, k = a.shape
    k2, n = b.shape
    assert k == k2, (a.shape, b.shape)
    ap = _pad_to(_pad_to(a.astype(jnp.int32), _BM, 0), _BK, 1)
    bp = _pad_to(_pad_to(b.astype(jnp.int32), _BK, 0), _BN, 1)
    biasp = _pad_to(bias.astype(jnp.int32), _BN, 0)
    mp, kp = ap.shape
    _, np_ = bp.shape
    shifts = jnp.stack([jnp.asarray(mul, jnp.int32), jnp.asarray(shift, jnp.int32)])

    out = pl.pallas_call(
        _gemm_kernel,
        grid=(mp // _BM, np_ // _BN, kp // _BK),
        in_specs=[
            pl.BlockSpec((_BM, _BK), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((_BK, _BN), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((_BN,), lambda i, j, kk: (j,)),
            pl.BlockSpec((2,), lambda i, j, kk: (0,)),
        ],
        out_specs=pl.BlockSpec((_BM, _BN), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.int32),
        scratch_shapes=[pltpu.VMEM((_BM, _BN), jnp.int32)],
        interpret=interpret,
    )(ap, bp, biasp, shifts)
    return out[:m, :n]
